//! Zero-dependency data parallelism over a persistent worker pool.
//!
//! The AdaRound hot paths (GEMM rows, conv groups, calibration chunks,
//! per-group rounding, the integer serving kernels) are embarrassingly
//! parallel, so this module provides exactly one pattern: split a range of
//! independent work items into contiguous per-thread spans and fan them
//! out to long-lived worker threads.
//!
//! **Determinism.** Work is assigned by *item index* and every item is
//! computed by the same serial code regardless of the thread count, so
//! results are bit-identical for `PALLAS_THREADS=1` and `=N` (verified by
//! the `*_bit_identical_across_threads` tests in tensor/ and adaround/,
//! and end-to-end by `rust/tests/pool_serving.rs`). No reduction-order
//! dependence: units only ever write disjoint sub-slices reconstructed
//! from a shared base pointer.
//!
//! **Thread count.** `PALLAS_THREADS` (clamped to [1, 256]) wins; otherwise
//! `std::thread::available_parallelism()`. Workers run their units with the
//! count forced to 1, so nested parallel calls (e.g. the row-parallel
//! matmul inside a row-flat conv) never resubmit to the pool and never
//! oversubscribe.
//!
//! **The pool.** Workers are spawned lazily on first parallel use and then
//! live for the process lifetime, parked on a condition variable between
//! calls. Replacing the former per-call `std::thread::scope` spawns
//! (~10-40us each) makes the many-small-layer serving regime and the
//! optimizer's per-step fan-outs pay only a queue push + unpark (~1us).
//! The pool grows on demand up to [`MAX_THREADS`] - 1 workers (the
//! submitting thread always executes the first unit itself) and is shared
//! by every submitting thread — e.g. all shard workers of a
//! [`crate::serve::Batcher`] — with FIFO unit dispatch.
//!
//! ```
//! use adaround::util::parallel;
//!
//! let mut data = vec![0u32; 1024];
//! parallel::par_chunks_mut(&mut data, 256, 1, |chunk_idx, chunk| {
//!     for v in chunk.iter_mut() {
//!         *v = chunk_idx as u32; // each unit owns a disjoint span
//!     }
//! });
//! assert_eq!(data[0], 0);
//! assert_eq!(data[1023], 3);
//! ```

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::util::topo;

/// Hard cap on worker threads (sanity bound for absurd env values).
pub const MAX_THREADS: usize = 256;

thread_local! {
    static OVERRIDE: Cell<Option<usize>> = const { Cell::new(None) };
    /// Core set the current thread's fan-outs should carry (set on shard
    /// worker threads by [`pin_thread_and_units`]; `None` = unpinned).
    static PIN_SET: RefCell<Option<Arc<[usize]>>> = const { RefCell::new(None) };
    /// Core set last applied to THIS thread — pool workers re-issue the
    /// affinity syscall only when a unit arrives from a submitter with a
    /// different set (`Arc` pointer comparison, so the steady state of a
    /// worker serving one shard is zero syscalls).
    static PIN_APPLIED: RefCell<Option<Arc<[usize]>>> = const { RefCell::new(None) };
}

/// Pin the calling thread to `cores` and tag every fan-out it submits so
/// pool workers running its units re-pin to the same set — the shard
/// placement mechanism of [`crate::serve::Batcher`]: a shard's nested
/// GEMM fan-out then executes entirely on the shard's cores. `None`
/// clears the tag (subsequent units re-open workers to the whole
/// machine). No-op when `PALLAS_NO_PIN` disables pinning; always a pure
/// placement hint, never a correctness dependency.
pub fn pin_thread_and_units(cores: Option<Arc<[usize]>>) {
    if !topo::pinning_enabled() {
        return;
    }
    if let Some(set) = &cores {
        topo::pin_current_thread(set);
    }
    PIN_SET.with(|c| c.borrow_mut().clone_from(&cores));
    PIN_APPLIED.with(|c| *c.borrow_mut() = cores);
}

fn env_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        let n = match std::env::var("PALLAS_THREADS") {
            Ok(v) => v.trim().parse::<usize>().unwrap_or(0),
            Err(_) => 0,
        };
        let n = if n == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            n
        };
        n.clamp(1, MAX_THREADS)
    })
}

/// Effective worker count for the current thread (env / override).
pub fn num_threads() -> usize {
    OVERRIDE.with(|c| c.get()).unwrap_or_else(env_threads)
}

/// Run `f` with the thread count forced to `n` on this thread (restored on
/// exit, panic-safe). Used by tests to compare thread counts within one
/// process, by the serving shards to divide the machine, and internally to
/// serialize nested parallelism in pool workers.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Guard(Option<usize>);
    impl Drop for Guard {
        fn drop(&mut self) {
            OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let prev = OVERRIDE.with(|c| c.replace(Some(n.clamp(1, MAX_THREADS))));
    let _g = Guard(prev);
    f()
}

/// Thread budget of consumer `i` when dividing `total` pool threads among
/// `parts` equal consumers (the serving shards, the registry's batchers):
/// the first `total % parts` consumers get one extra thread, and every
/// consumer gets at least one even when oversubscribed (`parts > total`).
/// Replaces the remainder-losing `total / parts` arithmetic — with 16
/// threads over 3 shards that split stranded a thread; this hands out
/// 6/5/5.
pub fn split_budget(total: usize, parts: usize, i: usize) -> usize {
    let parts = parts.max(1);
    let total = total.max(1);
    (total / parts + usize::from(i < total % parts)).max(1)
}

/// Split `n` items into at most `parts` contiguous near-equal ranges
/// (the first `n % parts` ranges get one extra item). Deterministic and
/// independent of thread scheduling.
pub fn split_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.clamp(1, n.max(1));
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut s = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        if len == 0 {
            break;
        }
        out.push(s..s + len);
        s += len;
    }
    out
}

// ---------------------------------------------------------------------------
// The persistent pool
// ---------------------------------------------------------------------------

/// One fan-out in flight: the type-erased task closure plus the
/// bookkeeping that lets the submitting thread block until every unit ran.
struct CallShared {
    /// The submitter's task closure with its lifetime erased so it can sit
    /// in the shared queue. Sound because [`run_on_pool`] never returns
    /// (not even by unwinding) until `remaining` reaches zero, and workers
    /// never touch this reference after their decrement.
    task: &'static (dyn Fn(usize) + Sync),
    /// Units still running on workers (the submitter's own unit 0 is not
    /// counted). The final `AcqRel` decrement publishes every worker's
    /// writes to the submitter's `Acquire` read.
    remaining: AtomicUsize,
    /// The submitting thread, unparked by whichever worker finishes last.
    caller: std::thread::Thread,
    /// First worker panic, re-thrown on the submitter after the wait.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    /// The submitter's core set at submit time ([`pin_thread_and_units`]):
    /// workers re-pin to it before running this call's units, so a pinned
    /// shard's work stays on the shard's cores.
    cores: Option<Arc<[usize]>>,
}

/// One queue entry: unit `idx` of `call`.
struct Unit {
    call: Arc<CallShared>,
    idx: usize,
}

struct Pool {
    queue: Mutex<VecDeque<Unit>>,
    available: Condvar,
    /// Workers spawned so far (atomic mirror for the lock-free hot-path
    /// check in [`Pool::ensure_workers`]); grows on demand, never shrinks.
    census: AtomicUsize,
    /// Serializes growth so two submitters can't double-spawn.
    grow: Mutex<()>,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        queue: Mutex::new(VecDeque::new()),
        available: Condvar::new(),
        census: AtomicUsize::new(0),
        grow: Mutex::new(()),
    })
}

/// Number of pool workers spawned so far. Purely observational (tests and
/// diagnostics); 0 until the first parallel call actually fans out.
pub fn pool_size() -> usize {
    pool().census.load(Ordering::Relaxed)
}

impl Pool {
    /// Ensure at least `want` workers exist. Workers are shared by all
    /// concurrent submitters, so this is a capacity floor, not a
    /// reservation: units queue FIFO and drain as workers free up. Once
    /// the pool is grown, this is a single relaxed load — no lock on the
    /// dispatch hot path.
    fn ensure_workers(&'static self, want: usize) {
        let want = want.min(MAX_THREADS - 1);
        if self.census.load(Ordering::Relaxed) >= want {
            return;
        }
        let _g = self.grow.lock().unwrap();
        let mut n = self.census.load(Ordering::Relaxed);
        while n < want {
            std::thread::Builder::new()
                .name(format!("pallas-worker-{n}"))
                .spawn(move || worker_loop(self))
                .expect("spawn pool worker");
            n += 1;
            self.census.store(n, Ordering::Relaxed);
        }
    }

    fn submit(&'static self, call: &Arc<CallShared>, units: Range<usize>) {
        // size the pool for AGGREGATE demand, not this one call: with
        // several concurrent submitters (e.g. serving shards each running
        // under a slice of the machine) each call's own fan-out is small,
        // but together they need the whole machine's worth of workers
        let k = units.len();
        self.ensure_workers(k.max(env_threads().saturating_sub(1)));
        let mut q = self.queue.lock().unwrap();
        for idx in units {
            q.push_back(Unit { call: Arc::clone(call), idx });
        }
        drop(q);
        // wake exactly as many workers as there are new units
        for _ in 0..k {
            self.available.notify_one();
        }
    }
}

/// Execute one queued unit (on a worker or a helping submitter): run the
/// task with nested parallelism forced serial, capture a panic into the
/// call, then decrement. The decrement must be the unit's LAST touch of
/// `call.task` — once `remaining` hits zero the submitter may return and
/// invalidate the borrow behind it.
fn run_unit(unit: &Unit) {
    apply_unit_pin(&unit.call);
    let task = unit.call.task;
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        with_threads(1, || task(unit.idx));
    }));
    if let Err(p) = result {
        let mut slot = unit.call.panic.lock().unwrap();
        if slot.is_none() {
            *slot = Some(p);
        }
    }
    if unit.call.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
        unit.call.caller.unpark();
    }
}

/// Adopt the unit's submitter affinity on the executing thread, skipping
/// the syscall when the last applied set is the same `Arc` (or both are
/// unpinned). An unpinned call after a pinned one re-opens the worker to
/// the whole machine.
fn apply_unit_pin(call: &CallShared) {
    if !topo::pinning_enabled() {
        return;
    }
    let stale = PIN_APPLIED.with(|c| {
        let cur = c.borrow();
        match (cur.as_ref(), call.cores.as_ref()) {
            (None, None) => false,
            (Some(a), Some(b)) => !Arc::ptr_eq(a, b),
            _ => true,
        }
    });
    if !stale {
        return;
    }
    match call.cores.as_ref() {
        Some(set) => topo::pin_current_thread(set),
        None => topo::pin_current_thread(topo::all_cores()),
    };
    PIN_APPLIED.with(|c| c.borrow_mut().clone_from(&call.cores));
}

fn worker_loop(pool: &'static Pool) {
    loop {
        let unit = {
            let mut q = pool.queue.lock().unwrap();
            loop {
                if let Some(u) = q.pop_front() {
                    break u;
                }
                q = pool.available.wait(q).unwrap();
            }
        };
        run_unit(&unit);
    }
}

/// Erase the task closure's lifetime so it can be shared with pool
/// workers.
///
/// # Safety
/// The caller must not let the closure (or anything it borrows) die until
/// every worker has finished with it — [`run_on_pool`] guarantees this by
/// blocking until `remaining == 0` on every exit path, unwinding included.
unsafe fn erase_lifetime<'a>(
    f: &'a (dyn Fn(usize) + Sync + 'a),
) -> &'static (dyn Fn(usize) + Sync + 'static) {
    std::mem::transmute::<&'a (dyn Fn(usize) + Sync + 'a), &'static (dyn Fn(usize) + Sync)>(f)
}

/// Run `n` task units `f(0) .. f(n-1)` across the persistent pool. The
/// submitting thread executes unit 0 inline (thread count forced to 1,
/// exactly like the workers), then parks until the rest finish. Panics
/// from any unit are re-thrown here — after every other unit has stopped,
/// so borrows stay valid throughout.
fn run_on_pool(n: usize, f: &(dyn Fn(usize) + Sync)) {
    if n <= 1 {
        with_threads(1, || f(0));
        return;
    }
    let call = Arc::new(CallShared {
        // SAFETY: this function blocks until `remaining == 0` before
        // returning or unwinding, so the erased borrow outlives all uses.
        task: unsafe { erase_lifetime(f) },
        remaining: AtomicUsize::new(n - 1),
        caller: std::thread::current(),
        panic: Mutex::new(None),
        cores: PIN_SET.with(|c| c.borrow().clone()),
    });
    pool().submit(&call, 1..n);
    let own = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        with_threads(1, || f(0));
    }));
    // wait for the workers, HELPING with this call's own still-queued
    // units instead of idling. Self-help (never foreign units — those
    // would head-of-line-block this call behind another call's long
    // work) guarantees progress even in the pathological case where
    // every worker is itself parked as a nested submitter (a unit that
    // re-raises its thread count via `with_threads`): each submitter can
    // always drain its own queued units itself.
    while call.remaining.load(Ordering::Acquire) != 0 {
        let own_unit = {
            let mut q = pool().queue.lock().unwrap();
            let pos = q.iter().position(|u| Arc::ptr_eq(&u.call, &call));
            pos.and_then(|i| q.remove(i))
        };
        match own_unit {
            Some(u) => run_unit(&u),
            None => std::thread::park(),
        }
    }
    if let Err(p) = own {
        std::panic::resume_unwind(p);
    }
    if let Some(p) = call.panic.lock().unwrap().take() {
        std::panic::resume_unwind(p);
    }
}

/// Base pointer handed across threads; every unit reconstructs only its
/// own disjoint span from it (enforced by the range arithmetic at the two
/// call sites below).
struct SendPtr<T>(*mut T);

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
// SAFETY: only used to rebuild disjoint `&mut` spans on units whose
// element type is `Send` (bounds at the call sites).
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

// ---------------------------------------------------------------------------
// Public fan-out entry points
// ---------------------------------------------------------------------------

/// Parallel split of `data` into per-thread spans of whole chunks: each
/// unit receives ONE contiguous range of chunk indices plus the matching
/// sub-slice, and `f(range, span)` processes it serially. This is the
/// primitive behind the K-blocked row-parallel GEMM, where a thread wants
/// its whole row range at once (to reuse cache blocks across rows) rather
/// than row-at-a-time callbacks.
///
/// `grain` is the minimum number of chunks per thread — below it the call
/// degrades to `f(0..nchunks, data)` on the caller thread (allocating
/// nothing and touching no pool state), so tiny inputs stay serial and the
/// optimizer's zero-allocation contract (`rust/tests/perf_invariants.rs`)
/// holds on the `PALLAS_THREADS=1` path.
///
/// Panics if `data.len()` is not a multiple of `chunk`.
pub fn par_ranges_mut<T, F>(data: &mut [T], chunk: usize, grain: usize, f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    assert!(chunk > 0, "chunk must be positive");
    assert_eq!(data.len() % chunk, 0, "data.len() {} not a multiple of chunk {}", data.len(), chunk);
    let nchunks = data.len() / chunk;
    let want = nchunks / grain.max(1);
    let t = num_threads().min(want.max(1));
    if t <= 1 || nchunks <= 1 {
        f(0..nchunks, data);
        return;
    }
    let ranges = split_ranges(nchunks, t);
    let base = SendPtr(data.as_mut_ptr());
    let ranges_ref = &ranges;
    let fr = &f;
    run_on_pool(ranges.len(), &move |ti: usize| {
        let r = ranges_ref[ti].clone();
        // SAFETY: `split_ranges` yields disjoint, in-bounds chunk ranges,
        // so every unit's span is a disjoint sub-slice of `data`.
        let span = unsafe {
            std::slice::from_raw_parts_mut(base.0.add(r.start * chunk), (r.end - r.start) * chunk)
        };
        fr(r, span);
    });
}

/// Parallel iteration over the equal-size chunks of `data`: calls
/// `f(chunk_index, chunk)` for every `chunk`-sized piece, fanning
/// contiguous runs of chunks out to pool workers (see [`par_ranges_mut`]
/// for grain semantics and the determinism contract).
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, grain: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    par_ranges_mut(data, chunk, grain, |range, span| {
        for (j, c) in span.chunks_mut(chunk).enumerate() {
            f(range.start + j, c);
        }
    });
}

/// [`par_ranges_mut`] specialization for GROUPED row work, the flat-index
/// fan-out of the grouped convolutions: rows belong to consecutive groups
/// of `rows_per_group`, a unit's contiguous row range is cut at group
/// boundaries, and `f(group, rows, seg)` runs once per segment with
/// global row indices and the matching sub-span. Keeping the cut
/// arithmetic here means the f32 and i8 conv paths can never diverge on
/// it. Grain/determinism semantics as in [`par_ranges_mut`].
pub fn par_grouped_rows_mut<T, F>(
    data: &mut [T],
    chunk: usize,
    rows_per_group: usize,
    grain: usize,
    f: F,
) where
    T: Send,
    F: Fn(usize, Range<usize>, &mut [T]) + Sync,
{
    assert!(rows_per_group > 0, "rows_per_group must be positive");
    par_ranges_mut(data, chunk, grain, |rows, span| {
        let mut r0 = rows.start;
        while r0 < rows.end {
            let g = r0 / rows_per_group;
            let r1 = ((g + 1) * rows_per_group).min(rows.end);
            let seg = &mut span[(r0 - rows.start) * chunk..(r1 - rows.start) * chunk];
            f(g, r0..r1, seg);
            r0 = r1;
        }
    });
}

/// Lock-step parallel iteration over the chunks of TWO slices: calls
/// `f(i, a_chunk_i, b_chunk_i)` for every chunk index. Both slices must
/// contain the same number of chunks (`a.len()/ca == b.len()/cb`); chunk
/// sizes may differ — e.g. a per-row output plus a per-row f64 partial.
/// Grain/determinism semantics as in [`par_ranges_mut`].
pub fn par_chunks2_mut<T, U, F>(a: &mut [T], ca: usize, b: &mut [U], cb: usize, grain: usize, f: F)
where
    T: Send,
    U: Send,
    F: Fn(usize, &mut [T], &mut [U]) + Sync,
{
    assert!(ca > 0 && cb > 0, "chunk sizes must be positive");
    assert_eq!(a.len() % ca, 0, "a.len() {} not a multiple of {}", a.len(), ca);
    assert_eq!(b.len() % cb, 0, "b.len() {} not a multiple of {}", b.len(), cb);
    let nchunks = a.len() / ca;
    assert_eq!(nchunks, b.len() / cb, "slices disagree on chunk count");
    let serial = |off: usize, aspan: &mut [T], bspan: &mut [U]| {
        for (j, (ac, bc)) in aspan.chunks_mut(ca).zip(bspan.chunks_mut(cb)).enumerate() {
            f(off + j, ac, bc);
        }
    };
    let want = nchunks / grain.max(1);
    let t = num_threads().min(want.max(1));
    if t <= 1 || nchunks <= 1 {
        serial(0, a, b);
        return;
    }
    let ranges = split_ranges(nchunks, t);
    let abase = SendPtr(a.as_mut_ptr());
    let bbase = SendPtr(b.as_mut_ptr());
    let ranges_ref = &ranges;
    let sr = &serial;
    run_on_pool(ranges.len(), &move |ti: usize| {
        let r = ranges_ref[ti].clone();
        // SAFETY: disjoint in-bounds ranges, as in `par_ranges_mut`, for
        // both slices in lock-step.
        let aspan = unsafe {
            std::slice::from_raw_parts_mut(abase.0.add(r.start * ca), (r.end - r.start) * ca)
        };
        let bspan = unsafe {
            std::slice::from_raw_parts_mut(bbase.0.add(r.start * cb), (r.end - r.start) * cb)
        };
        sr(r.start, aspan, bspan);
    });
}

/// Parallel map over `0..n`: returns `vec![f(0), f(1), ..]` in index order
/// regardless of scheduling. `grain` as in [`par_chunks_mut`].
pub fn par_map<R, F>(n: usize, grain: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    par_chunks_mut(&mut out, 1, grain, |i, slot| {
        slot[0] = Some(f(i));
    });
    out.into_iter().map(|r| r.expect("par_map slot filled")).collect()
}

/// [`par_map`] for stochastic work: item `i` draws from `rngs[i]`. Fork
/// the RNGs serially from one stream before calling (fork order = item
/// order), and the outcome is independent of the thread count — the
/// deterministic fan-out rule used by per-group rounding and per-chunk
/// calibration sampling.
pub fn par_map_rng<R, F>(rngs: &mut [crate::util::Rng], grain: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, &mut crate::util::Rng) -> R + Sync,
{
    let n = rngs.len();
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    par_chunks2_mut(&mut out, 1, rngs, 1, grain, |i, slot, rng| {
        slot[0] = Some(f(i, &mut rng[0]));
    });
    out.into_iter().map(|r| r.expect("par_map_rng slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ranges_covers_exactly() {
        for (n, p) in [(10, 3), (3, 10), (0, 4), (7, 1), (8, 8), (1, 1)] {
            let rs = split_ranges(n, p);
            let mut next = 0;
            for r in &rs {
                assert_eq!(r.start, next);
                assert!(r.end > r.start);
                next = r.end;
            }
            assert_eq!(next, n);
            assert!(rs.len() <= p.max(1));
            // near-equal: sizes differ by at most one
            if let (Some(a), Some(b)) = (
                rs.iter().map(|r| r.end - r.start).max(),
                rs.iter().map(|r| r.end - r.start).min(),
            ) {
                assert!(a - b <= 1);
            }
        }
    }

    #[test]
    fn split_budget_distributes_remainder() {
        for (total, parts) in [(16usize, 3usize), (8, 3), (9, 4), (4, 4), (7, 2), (1, 1)] {
            let budgets: Vec<usize> = (0..parts).map(|i| split_budget(total, parts, i)).collect();
            assert!(budgets.iter().all(|&b| b >= 1), "({total},{parts}): {budgets:?}");
            assert_eq!(budgets.iter().sum::<usize>(), total, "({total},{parts}) must lose nothing");
            let (mx, mn) = (budgets.iter().max().unwrap(), budgets.iter().min().unwrap());
            assert!(mx - mn <= 1, "({total},{parts}): near-equal split");
        }
        // the former arithmetic stranded the remainder: 16/3 gave 5+5+5;
        // the leading shards now absorb it
        assert_eq!(
            (0..3).map(|i| split_budget(16, 3, i)).collect::<Vec<_>>(),
            vec![6, 5, 5]
        );
        // oversubscribed: every shard still gets a thread
        assert!((0..5).map(|i| split_budget(2, 5, i)).all(|b| b == 1));
        assert_eq!(split_budget(0, 3, 0), 1, "degenerate totals floor at one");
    }

    #[test]
    fn pinned_fanout_is_bit_identical_to_unpinned() {
        let run = || with_threads(3, || par_map(16, 1, |i| i * 31 + 7));
        let base = run();
        let cores: Arc<[usize]> = Arc::from(topo::all_cores().to_vec());
        pin_thread_and_units(Some(cores));
        let pinned = run();
        pin_thread_and_units(None);
        let cleared = run();
        assert_eq!(base, pinned, "pinning may move threads, never results");
        assert_eq!(base, cleared);
    }

    #[test]
    fn par_chunks_mut_writes_every_chunk() {
        let mut data = vec![0u32; 7 * 13];
        with_threads(4, || {
            par_chunks_mut(&mut data, 13, 1, |i, c| {
                for (j, v) in c.iter_mut().enumerate() {
                    *v = (i * 13 + j) as u32;
                }
            });
        });
        for (k, v) in data.iter().enumerate() {
            assert_eq!(*v, k as u32);
        }
    }

    #[test]
    fn par_matches_serial() {
        let run = |threads: usize| {
            let mut data = vec![0.0f32; 101];
            with_threads(threads, || {
                par_chunks_mut(&mut data, 1, 1, |i, c| {
                    c[0] = (i as f32).sin();
                });
            });
            data
        };
        assert_eq!(run(1), run(5));
    }

    #[test]
    fn par_map_preserves_order() {
        let got = with_threads(3, || par_map(20, 1, |i| i * i));
        assert_eq!(got, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_rng_thread_count_independent() {
        let run = |threads: usize| {
            let mut base = crate::util::Rng::new(99);
            let mut rngs: Vec<crate::util::Rng> = (0..12).map(|i| base.fork(i)).collect();
            with_threads(threads, || par_map_rng(&mut rngs, 1, |i, r| (i, r.next_u64())))
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn nested_calls_serialize() {
        // inside a worker, num_threads() must report 1
        let inner: Vec<usize> = with_threads(4, || par_map(8, 1, |_| num_threads()));
        assert!(inner.iter().all(|&n| n == 1), "{inner:?}");
    }

    #[test]
    fn with_threads_restores() {
        let before = num_threads();
        with_threads(2, || {
            assert_eq!(num_threads(), 2);
            with_threads(7, || assert_eq!(num_threads(), 7));
            assert_eq!(num_threads(), 2);
        });
        assert_eq!(num_threads(), before);
    }

    #[test]
    fn par_chunks2_lockstep() {
        let rows = 9;
        let cols = 5;
        let mut grid = vec![0.0f32; rows * cols];
        let mut partial = vec![0.0f64; rows];
        with_threads(4, || {
            par_chunks2_mut(&mut grid, cols, &mut partial, 1, 1, |r, row, p| {
                for (j, v) in row.iter_mut().enumerate() {
                    *v = (r * cols + j) as f32;
                }
                p[0] = row.iter().map(|&v| v as f64).sum();
            });
        });
        for (k, v) in grid.iter().enumerate() {
            assert_eq!(*v, k as f32);
        }
        let expect: f64 = (0..cols).map(|j| (8 * cols + j) as f64).sum();
        assert_eq!(partial[8], expect);
    }

    #[test]
    fn grain_degrades_to_serial() {
        // grain larger than the chunk count: must still process everything
        let mut data = vec![0u8; 6];
        par_chunks_mut(&mut data, 2, 100, |_, c| c.iter_mut().for_each(|v| *v = 1));
        assert!(data.iter().all(|&v| v == 1));
    }

    #[test]
    fn pool_workers_are_reused_across_calls() {
        use std::collections::HashSet;
        use std::thread::ThreadId;
        // 20 fan-outs of 4 units each: per-call spawning would mint a
        // fresh thread per spawned unit (up to 60 distinct ids); a
        // persistent pool can only ever run units on its named workers
        // (or the submitter itself), so the distinct pool-worker count is
        // bounded by the pool census — an invariant that stays true
        // however concurrently-running tests grow the shared pool
        let on_pool_worker = || {
            std::thread::current()
                .name()
                .is_some_and(|n| n.starts_with("pallas-worker"))
        };
        let mut seen: HashSet<ThreadId> = HashSet::new();
        for _ in 0..20 {
            let ids = with_threads(4, || {
                par_map(4, 1, |_| (std::thread::current().id(), on_pool_worker()))
            });
            seen.extend(ids.into_iter().filter(|(_, pw)| *pw).map(|(id, _)| id));
        }
        assert!(pool_size() >= 1);
        assert!(
            seen.len() <= pool_size(),
            "{} distinct worker threads from a pool of {} — pool not persistent?",
            seen.len(),
            pool_size()
        );
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut data = vec![0u8; 8];
            with_threads(4, || {
                par_chunks_mut(&mut data, 1, 1, |i, _| {
                    assert!(i != 5, "intentional test panic on item 5");
                });
            });
        }));
        assert!(boom.is_err(), "panic in a unit must reach the submitter");
        // the pool must keep serving after a unit panicked
        let got = with_threads(4, || par_map(8, 1, |i| i + 1));
        assert_eq!(got, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_submitters_share_the_pool() {
        // several client threads fanning out at once (the sharded-serving
        // shape): every call must see exactly its own results
        let handles: Vec<_> = (0..4)
            .map(|c| {
                std::thread::spawn(move || {
                    with_threads(3, || par_map(30, 1, |i| c * 1000 + i))
                })
            })
            .collect();
        for (c, h) in handles.into_iter().enumerate() {
            let got = h.join().expect("client thread");
            assert_eq!(got, (0..30).map(|i| c * 1000 + i).collect::<Vec<_>>());
        }
    }
}
