//! Leveled stderr logger. Level from `QTZ_LOG` (error|warn|info|debug),
//! default `info`.

use std::sync::atomic::{AtomicU8, Ordering};

static LEVEL: AtomicU8 = AtomicU8::new(255);

#[derive(Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

pub fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != 255 {
        return l;
    }
    let v = match std::env::var("QTZ_LOG").unwrap_or_default().as_str() {
        "error" => 0,
        "warn" => 1,
        "debug" => 3,
        _ => 2,
    };
    LEVEL.store(v, Ordering::Relaxed);
    v
}

pub fn log(lvl: Level, msg: &str) {
    if (lvl as u8) <= level() {
        let tag = match lvl {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, &format!($($t)*)) };
}

#[macro_export]
macro_rules! warnlog {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, &format!($($t)*)) };
}

#[macro_export]
macro_rules! debuglog {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, &format!($($t)*)) };
}
