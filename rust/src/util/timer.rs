//! Wall-clock stopwatch + scoped phase timing for the perf pass.

use std::time::Instant;

pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let s = self.secs();
        self.start = Instant::now();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone() {
        let sw = Stopwatch::start();
        let a = sw.secs();
        let b = sw.secs();
        assert!(b >= a && a >= 0.0);
    }
}
