//! Minimal JSON parser + writer — the `serde_json` replacement.
//!
//! Parses the artifact manifest written by `python/compile/aot.py` and
//! serializes experiment reports. Supports the full JSON grammar except
//! `\u` surrogate pairs outside the BMP (not produced by our tooling).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Numbers are kept as f64 (all our payloads fit exactly).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // -------- typed accessors --------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn str_of(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow!("'{key}' not a string"))
    }

    pub fn usize_of(&self, key: &str) -> Result<usize> {
        self.req(key)?.as_usize().ok_or_else(|| anyhow!("'{key}' not a number"))
    }

    pub fn bool_of(&self, key: &str) -> Result<bool> {
        self.req(key)?.as_bool().ok_or_else(|| anyhow!("'{key}' not a bool"))
    }

    // -------- writer --------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report building.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}' at {}", c as char, self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got '{}' at {}", c as char, self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte utf-8: copy the full sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    let end = (start + len).min(self.bytes.len());
                    s.push_str(std::str::from_utf8(&self.bytes[start..end])?);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| anyhow!("bad number '{txt}': {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"x": true, "y": null}, "s": "he\"llo\n"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.req("b").unwrap().bool_of("x").unwrap(), true);
        assert_eq!(v.str_of("s").unwrap(), "he\"llo\n");
        // write -> reparse -> equal
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parse_nested_manifest_like() {
        let src = r#"{"models":{"m":{"ir":[{"id":"in","op":"input","inputs":[]}],
                     "weights":"m.qtz"}},"executables":[],"step_batch":192}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.usize_of("step_batch").unwrap(), 192);
        let ir = v.req("models").unwrap().req("m").unwrap().req("ir").unwrap();
        assert_eq!(ir.as_arr().unwrap()[0].str_of("op").unwrap(), "input");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""café ü""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ü");
    }

    #[test]
    fn numbers_precise() {
        let v = Json::parse("[0, -1, 3.25, 1e3, 192]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[4].as_usize().unwrap(), 192);
        assert_eq!(a[3].as_f64().unwrap(), 1000.0);
    }
}
