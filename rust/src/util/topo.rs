//! CPU topology detection and thread pinning for sharded serving.
//!
//! Zero-dependency by design (DESIGN.md §1): topology is read straight
//! from sysfs (`/sys/devices/system/node/node*/cpulist`, falling back to
//! `/sys/devices/system/cpu/online`, falling back to
//! `available_parallelism`), and pinning binds the calling thread with a
//! direct `sched_setaffinity(2)` FFI declaration — no libc crate. Both
//! are Linux-only; on other targets detection degrades to one synthetic
//! node and [`pin_current_thread`] is a quiet no-op returning `false`,
//! so the batcher's placement logic compiles and runs everywhere.
//!
//! Why pinning: the sharded [`crate::serve::Batcher`] gives each shard a
//! slice of the thread budget, but without affinity the kernel scheduler
//! is free to migrate every shard's threads across all cores (and across
//! NUMA nodes), defeating the cache- and memory-locality the sharding
//! exists to buy. [`shard_core_sets`] carves the machine into per-shard
//! core sets walking node-major order (a shard stays inside one node
//! whenever its budget fits), and the worker-pool plumbing in
//! [`crate::util::parallel`] re-pins pool workers to the submitting
//! shard's set for the duration of its units.
//!
//! Pinning never affects results — work assignment is by item index
//! ([`crate::util::parallel`]'s determinism contract), so affinity moves
//! *where* threads run, never *what* they compute. `PALLAS_NO_PIN=1` (or
//! the serve CLI's `--no-pin`) disables the whole mechanism.

use std::sync::OnceLock;

/// `PALLAS_NO_PIN` contract: same parsing as `PALLAS_NO_SIMD` — any
/// non-empty value other than `0` disables core pinning.
pub fn no_pin_requested(v: Option<&str>) -> bool {
    matches!(v.map(str::trim), Some(s) if !s.is_empty() && s != "0")
}

/// Whether this process may pin threads (the `PALLAS_NO_PIN` kill
/// switch, read once and cached).
pub fn pinning_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| !no_pin_requested(std::env::var("PALLAS_NO_PIN").ok().as_deref()))
}

/// Parse a sysfs cpulist (`"0-3,8,10-11"`) into core ids, in list order.
/// Malformed fields are skipped (sysfs is trusted but this must never
/// panic on an exotic kernel).
pub fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for field in s.trim().split(',') {
        let field = field.trim();
        if field.is_empty() {
            continue;
        }
        match field.split_once('-') {
            Some((a, b)) => {
                if let (Ok(a), Ok(b)) = (a.trim().parse::<usize>(), b.trim().parse::<usize>()) {
                    if a <= b && b - a < 4096 {
                        out.extend(a..=b);
                    }
                }
            }
            None => {
                if let Ok(v) = field.parse::<usize>() {
                    out.push(v);
                }
            }
        }
    }
    out
}

fn detect_nodes() -> Vec<Vec<usize>> {
    let mut nodes: Vec<(usize, Vec<usize>)> = Vec::new();
    if let Ok(dir) = std::fs::read_dir("/sys/devices/system/node") {
        for entry in dir.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let Some(id) = name.strip_prefix("node").and_then(|v| v.parse::<usize>().ok()) else {
                continue;
            };
            if let Ok(list) = std::fs::read_to_string(entry.path().join("cpulist")) {
                let cores = parse_cpulist(&list);
                if !cores.is_empty() {
                    nodes.push((id, cores));
                }
            }
        }
    }
    nodes.sort_by_key(|(id, _)| *id);
    if !nodes.is_empty() {
        return nodes.into_iter().map(|(_, c)| c).collect();
    }
    // no NUMA sysfs (non-Linux, containers hiding it): one synthetic node
    let online = std::fs::read_to_string("/sys/devices/system/cpu/online")
        .map(|s| parse_cpulist(&s))
        .unwrap_or_default();
    if !online.is_empty() {
        return vec![online];
    }
    let n = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    vec![(0..n).collect()]
}

/// Cores grouped by NUMA node, node id order (detected once). Always at
/// least one node with at least one core.
pub fn numa_nodes() -> &'static [Vec<usize>] {
    static NODES: OnceLock<Vec<Vec<usize>>> = OnceLock::new();
    NODES.get_or_init(detect_nodes)
}

/// Every usable core, node-major (all of node 0, then node 1, ...), so
/// consecutive slices of this list stay NUMA-local whenever they fit.
pub fn all_cores() -> &'static [usize] {
    static CORES: OnceLock<Vec<usize>> = OnceLock::new();
    CORES.get_or_init(|| numa_nodes().iter().flatten().copied().collect())
}

/// Carve per-shard core sets out of [`all_cores`]: shard `i` gets
/// `budgets[i]` consecutive cores (its thread budget), walking node-major
/// order from core slot `offset` and wrapping when the machine is
/// oversubscribed. Consecutive allocation is the NUMA placement: a shard
/// whose budget fits inside one node never straddles nodes, because
/// [`all_cores`] is node-major. `offset` lets a multi-model registry
/// stack several batchers onto disjoint slots.
pub fn shard_core_sets(budgets: &[usize], offset: usize) -> Vec<std::sync::Arc<[usize]>> {
    let cores = all_cores();
    let n = cores.len();
    let mut pos = offset;
    budgets
        .iter()
        .map(|&b| {
            let take = b.clamp(1, n);
            let set: Vec<usize> = (0..take).map(|j| cores[(pos + j) % n]).collect();
            pos += take;
            std::sync::Arc::from(set)
        })
        .collect()
}

/// Bind the calling thread to `cores` via `sched_setaffinity(2)`.
/// Returns `false` without side effects on non-Linux builds, empty or
/// out-of-range sets, or syscall failure (e.g. a container cpuset that
/// forbids the requested cores) — callers treat pinning as best-effort,
/// since placement never affects results.
pub fn pin_current_thread(cores: &[usize]) -> bool {
    #[cfg(target_os = "linux")]
    {
        // fixed 1024-bit mask, the kernel's compiled-in CPU_SETSIZE
        let mut mask = [0u64; 16];
        let mut any = false;
        for &c in cores {
            if c < 64 * mask.len() {
                mask[c / 64] |= 1u64 << (c % 64);
                any = true;
            }
        }
        if !any {
            return false;
        }
        extern "C" {
            fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
        }
        // SAFETY: `mask` is a valid initialized buffer of the size passed;
        // pid 0 targets the calling thread; the call reads the mask only.
        unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = cores;
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_pin_env_contract() {
        assert!(!no_pin_requested(None));
        assert!(!no_pin_requested(Some("")));
        assert!(!no_pin_requested(Some("0")));
        assert!(!no_pin_requested(Some(" 0 ")));
        assert!(no_pin_requested(Some("1")));
        assert!(no_pin_requested(Some("true")));
        assert!(no_pin_requested(Some("yes")));
    }

    #[test]
    fn cpulist_parsing() {
        assert_eq!(parse_cpulist("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("0-2,8,10-11\n"), vec![0, 1, 2, 8, 10, 11]);
        assert_eq!(parse_cpulist("5"), vec![5]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist("3-1"), Vec::<usize>::new(), "inverted range");
        assert_eq!(parse_cpulist("x,2,y-3"), vec![2], "garbage fields skipped");
    }

    #[test]
    fn topology_is_sane() {
        let nodes = numa_nodes();
        assert!(!nodes.is_empty());
        assert!(nodes.iter().all(|n| !n.is_empty()));
        let cores = all_cores();
        assert_eq!(cores.len(), nodes.iter().map(|n| n.len()).sum::<usize>());
    }

    #[test]
    fn shard_core_sets_are_disjoint_until_wrap() {
        let n = all_cores().len();
        let sets = shard_core_sets(&[2, 2, 1], 0);
        assert_eq!(sets.len(), 3);
        for s in &sets {
            assert!(!s.is_empty() && s.len() <= n.max(1));
        }
        // within machine capacity the sets must not overlap
        if n >= 5 {
            let mut seen = std::collections::BTreeSet::new();
            for s in &sets {
                for &c in s.iter() {
                    assert!(seen.insert(c), "core {c} assigned twice");
                }
            }
        }
        // offset shifts the walk: first core of the offset=1 carve is the
        // second core of the machine (mod wrap)
        let shifted = shard_core_sets(&[1], 1);
        assert_eq!(shifted[0][0], all_cores()[1 % n]);
        // zero-budget shards are floored to one core, never empty
        assert_eq!(shard_core_sets(&[0], 0)[0].len(), 1);
    }

    #[test]
    fn pinning_roundtrip_is_best_effort() {
        let cores = all_cores();
        // pin to the first core, then back to everything; on Linux inside
        // an unrestricted cpuset both succeed, anywhere else both must
        // no-op cleanly — the assertion is only on the consistency
        let one = pin_current_thread(&cores[..1]);
        let all = pin_current_thread(cores);
        if one {
            assert!(all, "widening a successful pin back to all cores must succeed");
        }
        assert!(!pin_current_thread(&[]), "empty set never pins");
    }
}
