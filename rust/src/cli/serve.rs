//! `serve`, `serve-bench` and `bench-diff` subcommands.
//!
//! `serve --listen ADDR` puts the zero-dependency HTTP front-end
//! ([`crate::serve::http`]) over a sharded batcher: `POST /v1/infer`,
//! Prometheus `GET /metrics`, `GET /healthz`, bounded admission
//! (429 past `--depth-budget` in-flight per shard) and a graceful drain
//! on SIGTERM/ctrl-c that answers every in-flight request before
//! exiting. `--synthetic` serves a tiny built-in model quantized
//! in-process — no artifacts needed (CI's socket smoke test). Multi-shard
//! layouts pin each shard to a NUMA-aware core set by default; `--no-pin`
//! (or `PALLAS_NO_PIN=1`) leaves placement to the scheduler.
//!
//! Multi-model + hot reload ([`crate::serve::registry`]): repeated
//! `--model id=path.qtz` flags register one model per bundle (routed at
//! `POST /v1/models/<id>/infer`; the first is the default behind
//! `/v1/infer`), `--arch NAME` picks the float architecture they share
//! (or `--synthetic` the built-in one), and `--watch` starts the mtime
//! watcher that hot-swaps a re-exported bundle with zero downtime
//! (`--watch-interval-ms`, default 500). `--export-synthetic PATH`
//! writes the built-in model's quantized bundle (vary weights with
//! `--seed`) and exits — the tool CI's hot-swap smoke uses to overwrite
//! a watched bundle mid-traffic.
//!
//! `serve-bench` quantizes (or loads) a model, compiles the integer
//! serving engine, and reports accuracy plus f32-vs-int8 throughput,
//! batched-serving latency percentiles, and the saturated closed-loop
//! throughput of a single engine vs a shard per core (`--shards`,
//! default: the thread count), written to `BENCH_serving.json`. See
//! `docs/SERVING.md` for the full quickstart and tuning guidance.
//!
//! `bench-diff a.json b.json` compares two `BENCH_*.json` files and exits
//! nonzero on regressions beyond `--tol` percent (default 10) — the CI
//! gate on the perf trajectory.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{Method, Pipeline, PipelineConfig, QuantizedModel};
use crate::eval::top1;
use crate::nn::{ForwardOptions, Model};
use crate::serve::{
    latency_entry, offered_load_latencies, shard_sweep, throughput_entry, BatchPolicy, Batcher,
    HttpConfig, HttpServer, ModelRegistry, ServeEngine, ServeMetrics, DEFAULT_MODEL_ID,
};
use crate::tensor::{IntTensor, Tensor};
use crate::util::cli::Args;
use crate::util::stats::percentile;
use crate::util::{parallel, Json, Rng, Stopwatch};

use super::common::{config_from_args, Ctx};

fn batch_of(x: &Tensor, n: usize) -> Tensor {
    let n = n.min(x.shape[0]);
    let per: usize = x.shape[1..].iter().product();
    Tensor::from_vec(
        &[n, x.shape[1], x.shape[2], x.shape[3]],
        x.data[..n * per].to_vec(),
    )
}

/// int8 engine top-1 over the validation set, batched.
fn engine_top1(engine: &mut ServeEngine, x: &Tensor, y: &IntTensor, batch: usize) -> f64 {
    let n = x.shape[0];
    let per: usize = x.shape[1..].iter().product();
    let mut correct = 0usize;
    for (s, e) in crate::data::chunks(n, batch) {
        let xb = Tensor::from_vec(
            &[e - s, x.shape[1], x.shape[2], x.shape[3]],
            x.data[s * per..e * per].to_vec(),
        );
        for (i, p) in engine.classify(&xb).iter().enumerate() {
            if *p as i32 == y.data[s + i] {
                correct += 1;
            }
        }
    }
    100.0 * correct as f64 / n as f64
}

/// Quantize with the serving defaults (8-bit nearest, per-channel, 8-bit
/// activations — each overridable) or load a previously exported `.qtz`
/// bundle when `--quantized` is given. Shared by `serve` and
/// `serve-bench` so both front doors accept the same flags.
fn load_or_quantize(
    args: &Args,
    ctx: &Ctx,
    model: &Model,
    calib: &Tensor,
) -> Result<QuantizedModel> {
    match args.opt("quantized") {
        Some(path) => crate::coordinator::load_quantized(path),
        None => {
            let mut cfg = config_from_args(args)?;
            if !args.flags.contains_key("method") {
                cfg.method = Method::Nearest;
            }
            if !args.flags.contains_key("bits") {
                cfg.bits = 8;
            }
            if !args.flags.contains_key("per-channel") {
                cfg.per_channel = true;
            }
            if cfg.act_bits.is_none() {
                cfg.act_bits = Some(8);
            }
            let pipe = Pipeline::new(model, cfg, Some(&ctx.rt));
            pipe.quantize(calib, &mut Rng::new(args.usize("seed", 1000)? as u64))
        }
    }
}

pub fn cmd_serve_bench(args: &Args) -> Result<()> {
    let ctx = Ctx::load(args)?;
    let name = args.str("model", "micro18");
    let model = ctx.model(&name)?;
    let (calib, _) = ctx.calib(&model)?;
    let val = ctx.val(&model)?;
    if model.task == "seg" {
        bail!("serve-bench covers classifiers; {name} is a segmentation model");
    }

    // quantize here (8-bit nearest by default — the serving sweet spot)
    // unless a previously exported bundle is given
    let qm = load_or_quantize(args, &ctx, &model, &calib)?;

    let mut engine = ServeEngine::compile(&model, &qm, &val.0.shape[1..])?;
    let kernel_name = engine.kernel().name();
    let op_choices = engine.plan.op_choices();
    let autotune_ms = engine.plan.autotune_ms;
    let opts = qm.opts();
    let fp = top1(&model, &val.0, &val.1, &ForwardOptions::default(), 64);
    let fq = top1(&model, &val.0, &val.1, &opts, 64);
    let iq = engine_top1(&mut engine, &val.0, &val.1, 64);
    println!("== serve-bench {name} (threads: {}) ==", parallel::num_threads());
    println!(
        "gemm kernel: {} (PALLAS_NO_SIMD forces portable; outputs are bit-identical either way)",
        engine.kernel().name()
    );
    // per-op autotuned variants (PALLAS_AUTOTUNE=0 pins the heuristic)
    println!(
        "autotune: {:.1} ms, per-op choices: {}",
        autotune_ms,
        op_choices
            .iter()
            .map(|(op, ch)| format!("{op}={}", ch.label()))
            .collect::<Vec<_>>()
            .join(" ")
    );
    println!("top-1: fp32 {fp:.2}%   fake-quant {fq:.2}%   int8 engine {iq:.2}%");
    let wb8 = engine.plan.weight_bytes();
    let dtypes = engine.plan.op_dtypes();
    let n_w4 = dtypes.iter().filter(|(_, d)| *d == "w4").count();
    println!(
        "plan: {wb8} packed weight bytes, {} gemm ops ({n_w4} w4, {} w8)",
        dtypes.len(),
        dtypes.len() - n_w4
    );

    // 4-bit twin: the same model re-quantized with 4-bit weights so the
    // bench compares the nibble-packed (w4) serve path against w8 at
    // batch 1, where weight bandwidth dominates. Skipped when serving a
    // pre-exported bundle — the bundle already fixed its layer widths.
    let mut engine4 = match args.opt("quantized") {
        Some(_) => None,
        None => {
            let mut cfg = config_from_args(args)?;
            if !args.flags.contains_key("method") {
                cfg.method = Method::Nearest;
            }
            cfg.bits = 4; // the point of this engine
            if !args.flags.contains_key("per-channel") {
                cfg.per_channel = true;
            }
            if cfg.act_bits.is_none() {
                cfg.act_bits = Some(8);
            }
            let pipe = Pipeline::new(&model, cfg, Some(&ctx.rt));
            let qm4 = pipe.quantize(&calib, &mut Rng::new(args.usize("seed", 1000)? as u64))?;
            Some(ServeEngine::compile(&model, &qm4, &val.0.shape[1..])?)
        }
    };
    let mut wb4 = None;
    if let Some(e4) = &mut engine4 {
        let bytes = e4.plan.weight_bytes();
        let i4 = engine_top1(e4, &val.0, &val.1, 64);
        println!(
            "int4 twin: top-1 {i4:.2}%, {bytes} packed weight bytes ({:.2}x smaller than w8)",
            wb8 as f64 / bytes.max(1) as f64
        );
        wb4 = Some((bytes, i4));
    }

    let mut results: Vec<Json> = Vec::new();
    // compile-time autotuning cost as a bench entry (mean_ms so
    // bench-diff's regression gate covers it once a baseline records it)
    results.push({
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str("plan autotune".to_string()));
        o.insert("mean_ms".to_string(), Json::Num(autotune_ms));
        Json::Obj(o)
    });
    let reps = args.usize("reps", 10)?;
    println!(
        "{:<26} {:>12} {:>12} {:>12} {:>8}",
        "batch", "f32 img/s", "int8 img/s", "int4 img/s", "speedup"
    );
    for batch in [1usize, 8, 32, 64] {
        if batch > val.0.shape[0] {
            continue; // val set too small for an honest measurement
        }
        let xb = batch_of(&val.0, batch);
        let f32_s = {
            let sw = Stopwatch::start();
            for _ in 0..reps {
                std::hint::black_box(model.forward(&xb, &opts));
            }
            sw.secs() / reps as f64
        };
        let int8_s = {
            let sw = Stopwatch::start();
            for _ in 0..reps {
                std::hint::black_box(engine.forward(&xb));
            }
            sw.secs() / reps as f64
        };
        let int4_tp = engine4.as_mut().map(|e4| {
            let sw = Stopwatch::start();
            for _ in 0..reps {
                std::hint::black_box(e4.forward(&xb));
            }
            batch as f64 / (sw.secs() / reps as f64)
        });
        let (f32_tp, int8_tp) = (batch as f64 / f32_s, batch as f64 / int8_s);
        println!(
            "{:<26} {:>12.1} {:>12.1} {:>12} {:>7.2}x",
            format!("batch {batch}"),
            f32_tp,
            int8_tp,
            int4_tp.map_or("-".to_string(), |t| format!("{t:.1}")),
            int8_tp / f32_tp
        );
        results.push(throughput_entry(&format!("f32-fake-quant batch{batch}"), f32_tp));
        results.push(throughput_entry(&format!("int8-engine batch{batch}"), int8_tp));
        if let Some(tp) = int4_tp {
            results.push(throughput_entry(&format!("int4-engine batch{batch}"), tp));
        }
    }

    // batched serving under offered load, sharded across --shards engines
    let shards = args.usize("shards", parallel::num_threads())?.max(1);
    let policy = BatchPolicy {
        max_batch: args.usize("max-batch", 32)?,
        max_wait: Duration::from_millis(args.usize("max-wait-ms", 3)? as u64),
        shards,
        // effectively unbounded: the latency entries measure queueing,
        // not admission control, and must stay comparable to the
        // pre-admission baselines
        depth_budget: 4096,
        pin: !args.bool("no-pin"),
    };
    let per: usize = val.0.shape[1..].iter().product();
    let pool: Vec<Tensor> = (0..16.min(val.0.shape[0]))
        .map(|i| {
            Tensor::from_vec(
                &val.0.shape[1..],
                val.0.data[i * per..(i + 1) * per].to_vec(),
            )
        })
        .collect();
    let batcher = Batcher::new(engine, policy);
    let lat_head = format!("offered load ({shards} shards)");
    println!("{lat_head:<26} {:>12} {:>12}", "p50 ms", "p99 ms");
    for rate in [500.0f64, 2000.0, 8000.0] {
        let n_req = (rate * 0.5) as usize;
        let lat = offered_load_latencies(&batcher, &pool, n_req.max(50), rate);
        let (p50, p99) = (percentile(&lat, 50.0), percentile(&lat, 99.0));
        println!("{:<26} {:>12.2} {:>12.2}", format!("{rate:.0} img/s"), p50, p99);
        results.push(latency_entry(&format!("serve offered={rate:.0}"), p50, p99));
    }
    batcher.shutdown();

    // batch-heavy saturation: single engine vs a shard per core — the
    // multi-core serving headline (closed loop, queue never dry)
    let (entries, _speedup) = shard_sweep(
        || ServeEngine::compile(&model, &qm, &val.0.shape[1..]).expect("engine compiled above"),
        policy,
        &pool,
        shards,
        26,
    );
    results.extend(entries);

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("serving".to_string()));
    root.insert("model".to_string(), Json::Str(name));
    root.insert("threads".to_string(), Json::Num(parallel::num_threads() as f64));
    root.insert("kernel".to_string(), Json::Str(kernel_name.to_string()));
    root.insert("shards".to_string(), Json::Num(shards as f64));
    root.insert("top1_fp32".to_string(), Json::Num(fp));
    root.insert("top1_fake_quant".to_string(), Json::Num(fq));
    root.insert("top1_int8".to_string(), Json::Num(iq));
    // weight footprint + per-op dtype of the compiled plan(s) — the model
    // size axis of the w8/w4 trade-off
    root.insert("weight_bytes_w8".to_string(), Json::Num(wb8 as f64));
    if let Some((bytes, i4)) = wb4 {
        root.insert("weight_bytes_w4".to_string(), Json::Num(bytes as f64));
        root.insert("top1_int4".to_string(), Json::Num(i4));
    }
    root.insert(
        "op_dtypes".to_string(),
        Json::Arr(dtypes.iter().map(|(n, d)| Json::Str(format!("{n}:{d}"))).collect()),
    );
    root.insert(
        "op_kernels".to_string(),
        Json::Arr(
            op_choices.iter().map(|(n, ch)| Json::Str(format!("{n}:{}", ch.label()))).collect(),
        ),
    );
    root.insert("autotune_ms".to_string(), Json::Num(autotune_ms));
    root.insert("results".to_string(), Json::Arr(results));
    std::fs::write("BENCH_serving.json", Json::Obj(root).to_string_pretty())?;
    println!("(wrote BENCH_serving.json)");
    if (fq - iq).abs() > 0.2 {
        bail!("int8 engine top-1 {iq:.2}% drifted >0.2% from fake-quant {fq:.2}%");
    }
    Ok(())
}

/// Zero-dependency Unix signal latch for the graceful drain: `signal(2)`
/// from libc (already linked by std), a static flag flipped in the
/// handler, polled by the serve loop. Windows builds just never see the
/// flag set (ctrl-c kills the process, as before).
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TERM: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_signal(_signum: i32) {
        // async-signal-safe: one atomic store, nothing else
        TERM.store(true, Ordering::SeqCst);
    }

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    pub fn install() {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGINT, on_signal);
            signal(SIGTERM, on_signal);
        }
    }

    pub fn requested() -> bool {
        TERM.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sig {
    pub fn install() {}

    pub fn requested() -> bool {
        false
    }
}

/// The float architecture of the tiny self-contained classifier
/// ([3,16,16] conv→gpool→dense) behind `--synthetic` and
/// `--export-synthetic`. `weight_seed` draws the weights — two seeds
/// give two models with distinct outputs, which is exactly what the
/// hot-swap smoke needs to observe a generation change end to end.
fn synthetic_model(weight_seed: u64) -> Result<Model> {
    let ir = r#"{"task":"cls","ir":[
      {"id":"in","op":"input","inputs":[]},
      {"id":"c1","op":"conv","inputs":["in"],"cin":3,"cout":8,
       "k":3,"stride":1,"pad":1,"groups":1,"relu":true},
      {"id":"g1","op":"gpool","inputs":["c1"]},
      {"id":"d1","op":"dense","inputs":["g1"],"cin":8,"cout":4,"relu":false}
    ]}"#;
    let mut rng = Rng::new(weight_seed);
    let mut w = BTreeMap::new();
    for (name, shape, std) in [
        ("c1.w", vec![8usize, 3, 3, 3], 0.25f32),
        ("c1.b", vec![8], 0.05),
        ("d1.w", vec![4, 8], 0.4),
        ("d1.b", vec![4], 0.05),
    ] {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal_f32(0.0, std)).collect();
        w.insert(name.to_string(), Tensor::from_vec(&shape, data));
    }
    Model::from_manifest("synthetic", &Json::parse(ir)?, w)
}

/// Synthetic model + its 8/8-nearest quantization. Seed 7 is the
/// historical `serve --synthetic` model, bit for bit.
fn synthetic_parts(weight_seed: u64) -> Result<(Model, QuantizedModel)> {
    let model = synthetic_model(weight_seed)?;
    let mut rng = Rng::new(weight_seed.wrapping_add(1000));
    let (calib, _) = crate::data::synthetic_stripes(32, 3, 16, &mut rng);
    let cfg = PipelineConfig {
        method: Method::Nearest,
        bits: 8,
        per_channel: true,
        act_bits: Some(8),
        calib_n: calib.shape[0],
        ..Default::default()
    };
    let qm = Pipeline::new(&model, cfg, None).quantize(&calib, &mut Rng::new(1))?;
    Ok((model, qm))
}

/// A tiny self-contained classifier quantized in-process — `serve
/// --synthetic` boots without artifacts, which is what CI's socket
/// smoke test runs against.
fn synthetic_engine() -> Result<ServeEngine> {
    let (model, qm) = synthetic_parts(7)?;
    ServeEngine::compile(&model, &qm, &[3, 16, 16])
}

/// Parse repeated `--model id=path.qtz` flags; a bare `--model NAME`
/// (no '=') is the legacy architecture selector, not a registry entry.
fn model_specs(args: &Args) -> Vec<(String, String)> {
    args.all("model")
        .iter()
        .filter_map(|m| m.split_once('='))
        .map(|(id, path)| (id.to_string(), path.to_string()))
        .collect()
}

pub fn cmd_serve(args: &Args) -> Result<()> {
    // --export-synthetic PATH: write the built-in model's bundle and
    // exit. `--seed N` varies the weights, so re-exporting with a new
    // seed over a watched path exercises a real hot-swap.
    if let Some(path) = args.opt("export-synthetic") {
        let seed = args.usize("seed", 7)? as u64;
        let (_, qm) = synthetic_parts(seed)?;
        crate::coordinator::save_quantized(path, &qm)?;
        println!("exported synthetic .qtz bundle (weight seed {seed}) to {path}");
        return Ok(());
    }
    let listen = args.str("listen", "127.0.0.1:8780");
    let policy = BatchPolicy {
        max_batch: args.usize("max-batch", 32)?,
        max_wait: Duration::from_millis(args.usize("max-wait-ms", 3)? as u64),
        shards: args.usize("shards", parallel::num_threads())?.max(1),
        depth_budget: args.usize("depth-budget", 128)?.max(1),
        pin: !args.bool("no-pin"),
    };
    let cfg = HttpConfig {
        auth_token: args.opt("auth-token").map(|s| s.to_string()),
        ..Default::default()
    };
    let watch = args.bool("watch");
    let interval = Duration::from_millis(args.usize("watch-interval-ms", 500)? as u64);
    let specs = model_specs(args);
    let mut builder = ModelRegistry::builder();
    if !specs.is_empty() {
        // multi-model registry: every bundle shares one float
        // architecture — the built-in one under --synthetic, else
        // --arch from the artifact store
        if args.bool("synthetic") {
            for (id, path) in &specs {
                builder = builder.register_qtz(id, synthetic_model(7)?, path, &[3, 16, 16], policy)?;
            }
        } else {
            let ctx = Ctx::load(args)?;
            let name = args.str("arch", "micro18");
            let model = ctx.model(&name)?;
            if model.task == "seg" {
                bail!("serve covers classifiers; {name} is a segmentation model");
            }
            let (calib, _) = ctx.calib(&model)?;
            let in_shape = calib.shape[1..].to_vec();
            for (id, path) in &specs {
                builder = builder.register_qtz(id, model.clone(), path, &in_shape, policy)?;
            }
        }
    } else if args.bool("synthetic") {
        builder = builder.register(DEFAULT_MODEL_ID, synthetic_engine()?, policy)?;
    } else {
        let ctx = Ctx::load(args)?;
        let name = args.str("model", "micro18");
        let model = ctx.model(&name)?;
        if model.task == "seg" {
            bail!("serve covers classifiers; {name} is a segmentation model");
        }
        let (calib, _) = ctx.calib(&model)?;
        let in_shape = calib.shape[1..].to_vec();
        match args.opt("quantized") {
            // a bundle on disk: register reloadable so --watch works
            Some(path) => {
                builder = builder.register_qtz(DEFAULT_MODEL_ID, model, path, &in_shape, policy)?;
            }
            None => {
                let qm = load_or_quantize(args, &ctx, &model, &calib)?;
                let engine = ServeEngine::compile(&model, &qm, &in_shape)?;
                builder = builder.register(DEFAULT_MODEL_ID, engine, policy)?;
            }
        }
    }
    sig::install();
    let registry = if watch { builder.build_watched(interval)? } else { builder.build()? };
    if watch && !registry.watching() {
        println!("note: --watch has nothing to do (no model is backed by a .qtz bundle)");
    }
    let server = HttpServer::bind_registry(registry, &listen, cfg)?;
    println!(
        "serving on http://{}  ({} shards/model, depth budget {}/model; POST /v1/infer, POST /v1/models/<id>/infer, GET /metrics, GET /healthz)",
        server.local_addr(),
        policy.shards,
        policy.depth_budget * policy.shards,
    );
    let mut model_metrics: Vec<(String, Arc<ServeMetrics>)> = Vec::new();
    if let Some(reg) = server.registry() {
        for (id, entry) in reg.entries() {
            let stamp = entry.stamp();
            let src = entry
                .qtz_path()
                .map(|p| format!("{}{}", p.display(), if reg.watching() { " (watched)" } else { "" }))
                .unwrap_or_else(|| "in-process".to_string());
            println!(
                "  model '{id}': plan {} generation {} — {src}",
                stamp.id_hex, stamp.generation
            );
            model_metrics.push((id.to_string(), Arc::clone(entry.metrics())));
        }
    }
    println!("SIGTERM or ctrl-c drains: in-flight requests finish, then the pool joins");
    // --drain-after-secs: self-terminate (tests and demos; 0 = run until
    // signalled)
    let drain_after = args.f32("drain-after-secs", 0.0)? as f64;
    let start = Instant::now();
    while !sig::requested() {
        if drain_after > 0.0 && start.elapsed().as_secs_f64() >= drain_after {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    println!("draining...");
    server.shutdown();
    let (mut answered, mut full, mut drain, mut shape) = (0u64, 0u64, 0u64, 0u64);
    for (id, m) in &model_metrics {
        answered += m.responses.get();
        full += m.rejected_full.get();
        drain += m.rejected_draining.get();
        shape += m.rejected_shape.get();
        if model_metrics.len() > 1 {
            println!(
                "  model '{id}': {} answered, {} reloads ok, {} reloads failed",
                m.responses.get(),
                m.reloads_ok.get(),
                m.reloads_failed.get()
            );
        }
    }
    println!(
        "drained: {answered} answered, {} rejected (queue_full {full}, draining {drain}, bad_shape {shape})",
        full + drain + shape,
    );
    Ok(())
}

/// Numeric fields where smaller is better / bigger is better.
const LOWER_BETTER: &[&str] = &["mean_ms", "p50_ms", "p95_ms", "p99_ms"];
const HIGHER_BETTER: &[&str] = &["throughput", "imgs_per_sec"];

pub fn cmd_bench_diff(args: &Args) -> Result<()> {
    let a_path = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: bench-diff <baseline.json> <new.json> [--tol PCT]"))?;
    let b_path = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: bench-diff <baseline.json> <new.json> [--tol PCT]"))?;
    let tol = args.f32("tol", 10.0)? as f64;
    let a = Json::parse(&std::fs::read_to_string(a_path)?)?;
    let b = Json::parse(&std::fs::read_to_string(b_path)?)?;
    let index = |j: &Json| -> BTreeMap<String, BTreeMap<String, f64>> {
        let mut out = BTreeMap::new();
        if let Some(entries) = j.get("results").and_then(|r| r.as_arr()) {
            for e in entries {
                let Some(name) = e.get("name").and_then(|n| n.as_str()) else { continue };
                let mut fields = BTreeMap::new();
                if let Some(obj) = e.as_obj() {
                    for (k, v) in obj {
                        if let Some(n) = v.as_f64() {
                            fields.insert(k.clone(), n);
                        }
                    }
                }
                out.insert(name.to_string(), fields);
            }
        }
        out
    };
    let base = index(&a);
    let new = index(&b);
    let mut regressions = Vec::new();
    let mut compared = 0usize;
    for (name, bf) in &base {
        let Some(nf) = new.get(name) else { continue };
        for (key, lower_better) in LOWER_BETTER
            .iter()
            .map(|k| (*k, true))
            .chain(HIGHER_BETTER.iter().map(|k| (*k, false)))
        {
            let (Some(&old), Some(&cur)) = (bf.get(key), nf.get(key)) else { continue };
            if old <= 0.0 {
                continue;
            }
            compared += 1;
            let change = 100.0 * (cur - old) / old;
            let regressed = if lower_better { change > tol } else { change < -tol };
            let marker = if regressed { "  <-- REGRESSION" } else { "" };
            println!(
                "{name:<44} {key:<14} {old:>12.3} -> {cur:>12.3}  ({change:+6.1}%){marker}"
            );
            if regressed {
                regressions.push(format!("{name} {key} {change:+.1}%"));
            }
        }
    }
    let shared = base.keys().filter(|k| new.contains_key(*k)).count();
    println!("compared {compared} metric(s) across {shared} shared entries");
    if !regressions.is_empty() {
        bail!(">{tol}% regressions:\n  {}", regressions.join("\n  "));
    }
    Ok(())
}
