//! `quantize`, `eval`, `bench-engine` and `quantize-bench` subcommands.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::adaround::AdaRoundConfig;
use crate::coordinator::{Method, Pipeline, PipelineConfig};
use crate::data::{synthetic_stripes, synthetic_tokens};
use crate::nn::{ForwardOptions, Model};
use crate::tensor::{im2col, Conv2dParams, Tensor};
use crate::util::cli::Args;
use crate::util::{parallel, Json, Rng, Stopwatch};

use super::common::{config_from_args, first_layer, Ctx};

pub fn cmd_eval(args: &Args) -> Result<()> {
    let ctx = Ctx::load(args)?;
    let name = args.str("model", "micro18");
    let model = ctx.model(&name)?;
    let val = ctx.val(&model)?;
    // --quantized <bundle.qtz>: evaluate a previously exported model
    if let Some(path) = args.opt("quantized") {
        let qm = crate::coordinator::load_quantized(path)?;
        let m = ctx.metric(&model, &val.0, &val.1, &qm.opts());
        println!("{name}: quantized bundle {path} -> {m:.2}%");
        return Ok(());
    }
    let sw = Stopwatch::start();
    let m = ctx.metric(&model, &val.0, &val.1, &ForwardOptions::default());
    println!(
        "{name}: fp32 {} = {m:.2}%  ({} images, {:.1}s; trained ref {:.2}%)",
        if model.task == "seg" { "mIOU" } else { "top-1" },
        val.0.shape[0],
        sw.secs(),
        ctx.rt.manifest.fp32_metric(&name).unwrap_or(f64::NAN),
    );
    Ok(())
}

pub fn cmd_quantize(args: &Args) -> Result<()> {
    // the synthetic transformer is artifact-free (no `make artifacts`
    // runtime, no datasets): model + token calibration are built
    // in-process, so branch before loading the context
    if args.bool("synthetic-transformer") {
        return cmd_quantize_transformer(args);
    }
    let ctx = Ctx::load(args)?;
    let name = args.str("model", "micro18");
    let model = ctx.model(&name)?;
    let mut cfg = config_from_args(args)?;
    if args.bool("first-layer") {
        cfg.only_layers = Some(first_layer(&model));
    }
    if let Some(id) = args.opt("layer") {
        cfg.only_layers = Some(vec![id.to_string()]);
    }
    let (calib, _) = ctx.calib(&model)?;
    let val = ctx.val(&model)?;
    let mut rng = Rng::new(args.usize("seed", 1000)? as u64);

    let sw = Stopwatch::start();
    let pipe = Pipeline::new(&model, cfg.clone(), Some(&ctx.rt));
    let qm = pipe.quantize(&calib, &mut rng)?;
    let q_secs = sw.secs();

    let fp = ctx.metric(&pipe.work, &val.0, &val.1, &ForwardOptions::default());
    let acc = ctx.metric(&pipe.work, &val.0, &val.1, &qm.opts());

    println!("== {} | method={} bits={} act={:?} grid={:?} pc={} asym={} relu={}",
             name, cfg.method.name(), cfg.bits, cfg.act_bits, cfg.grid,
             cfg.per_channel, cfg.asymmetric, cfg.use_relu);
    println!("{:<6} {:>5}x{:<5} {:>3} {:>12} {:>12} {:>8} {:>7}",
             "layer", "rows", "cols", "g", "mse(nearest)", "mse(after)", "flip%", "secs");
    for s in &qm.stats {
        println!(
            "{:<6} {:>5}x{:<5} {:>3} {:>12.3e} {:>12.3e} {:>7.1}% {:>6.1}s",
            s.id, s.rows, s.cols, s.groups, s.mse_before, s.mse_after,
            100.0 * s.flipped_frac, s.secs
        );
    }
    // per-layer weight widths + the packed size serving will actually
    // ship (i4 nibble-packs two weights per byte)
    if !qm.wbits.is_empty() {
        let (mut wsum, mut bsum, mut packed) = (0usize, 0u64, 0usize);
        println!("{:<6} {:>5} {:>14}", "layer", "wbits", "packed bytes");
        for s in &qm.stats {
            let Some(&b) = qm.wbits.get(&s.id) else { continue };
            let params = s.rows * s.cols * s.groups;
            let bytes = if b <= 4 { params.div_ceil(2) } else { params };
            println!("{:<6} {:>5} {:>14}", s.id, b, bytes);
            wsum += params;
            bsum += b as u64 * params as u64;
            packed += bytes;
        }
        println!(
            "weight assignment: mean {:.2} bits, {packed} packed weight bytes{}",
            bsum as f64 / wsum.max(1) as f64,
            match cfg.bit_budget {
                Some(t) => format!(" (budget {t} bits/weight)"),
                None => String::new(),
            }
        );
    }
    println!(
        "fp32 {fp:.2}%  ->  quantized {acc:.2}%   (quantize {q_secs:.1}s, \
         {} calibration layer-forwards [{} sampler], {} executables compiled)",
        qm.layer_execs,
        if cfg.replay_sampler { "O(L²) replay" } else { "O(L) streaming" },
        ctx.rt.compiled_count()
    );
    if let Some(path) = args.opt("save") {
        crate::coordinator::save_quantized(path, &qm)?;
        println!("quantized model saved to {path}");
    }
    Ok(())
}

/// `quantize --synthetic-transformer`: quantize the synthetic
/// transformer end-to-end through the streaming pipeline (per-head grids
/// for the Q/K/V projections, full attention subgraph in the activation
/// store). Calibration data is a seeded token set; there is no
/// validation metric — the reported objective is per-layer recon-MSE.
/// `--assert-beats-nearest` turns "total recon-MSE improved over
/// round-to-nearest" into the exit status (the CI transformer smoke).
fn cmd_quantize_transformer(args: &Args) -> Result<()> {
    let depth = args.usize("depth", 2)?;
    let heads = args.usize("heads", 2)?;
    let d_model = args.usize("d-model", 16)?;
    let seq = args.usize("seq", 8)?;
    let cfg = config_from_args(args)?;
    let model = Model::synthetic_transformer(depth, heads, d_model, seq, &mut Rng::new(5));
    let calib = synthetic_tokens(
        cfg.calib_n,
        seq,
        crate::nn::graph::TRANSFORMER_VOCAB,
        &mut Rng::new(9),
    );
    let mut rng = Rng::new(args.usize("seed", 1000)? as u64);

    let sw = Stopwatch::start();
    let pipe = Pipeline::new(&model, cfg.clone(), None);
    let qm = pipe.quantize(&calib, &mut rng)?;
    let q_secs = sw.secs();

    println!(
        "== {} | method={} bits={} act={:?} grid={:?} pc={} asym={} heads={}",
        model.name, cfg.method.name(), cfg.bits, cfg.act_bits, cfg.grid,
        cfg.per_channel, cfg.asymmetric, heads
    );
    println!("{:<8} {:>5}x{:<5} {:>3} {:>12} {:>12} {:>8} {:>7}",
             "layer", "rows", "cols", "g", "mse(nearest)", "mse(after)", "flip%", "secs");
    for s in &qm.stats {
        println!(
            "{:<8} {:>5}x{:<5} {:>3} {:>12.3e} {:>12.3e} {:>7.1}% {:>6.1}s",
            s.id, s.rows, s.cols, s.groups, s.mse_before, s.mse_after,
            100.0 * s.flipped_frac, s.secs
        );
    }
    let (before, after) = (qm.total_mse_before(), qm.total_mse_after());
    println!(
        "recon-MSE total: nearest {before:.4e} -> {} {after:.4e}   \
         (quantize {q_secs:.1}s, {} calibration layer-forwards [{} sampler])",
        cfg.method.name(),
        qm.layer_execs,
        if cfg.replay_sampler { "O(L²) replay" } else { "O(L) streaming" },
    );
    if args.bool("assert-beats-nearest") && after >= before {
        bail!(
            "{} did not beat nearest rounding on recon-MSE ({after:.4e} >= {before:.4e})",
            cfg.method.name()
        );
    }
    Ok(())
}

/// Parameters of the pipeline benchmark (`adaround quantize-bench` and
/// `benches/pipeline.rs` share this harness).
pub struct QuantizeBenchOpts {
    /// conv depth of the synthetic model (quant layers = depth + 1)
    pub depth: usize,
    /// channel width of the synthetic model
    pub ch: usize,
    pub calib_n: usize,
    /// AdaRound iterations (kept small: the bench measures the pipeline,
    /// not the optimizer)
    pub iters: usize,
    /// output JSON path
    pub out: String,
}

impl Default for QuantizeBenchOpts {
    fn default() -> Self {
        QuantizeBenchOpts {
            depth: 16,
            ch: 8,
            calib_n: 128,
            iters: 100,
            out: "BENCH_pipeline.json".to_string(),
        }
    }
}

/// End-to-end `quantize` wall-clock + calibration layer-forward counts on
/// a deep synthetic model, streaming vs full-replay sampler, per method.
/// Self-contained (no `make artifacts`). Emits `BENCH_pipeline.json` for
/// `bench-diff` and FAILS if the two samplers disagree on the produced
/// weights — the CI bench run doubles as an equivalence gate.
pub fn run_quantize_bench(o: &QuantizeBenchOpts) -> Result<()> {
    let mut rng = Rng::new(4242);
    let model = Model::synthetic_chain(o.depth, o.ch, true, &mut rng);
    let (calib, _) = synthetic_stripes(o.calib_n, 3, 16, &mut rng);
    let n_layers = model.quant_layers().len();
    println!(
        "== pipeline benchmarks (synthetic depth {}, {} quant layers, calib {}, threads {}) ==",
        o.depth,
        n_layers,
        o.calib_n,
        parallel::num_threads()
    );
    println!("{:<12} {:<10} {:>10} {:>16}", "method", "sampler", "secs", "layer-forwards");

    let mut results: Vec<Json> = Vec::new();
    let (mut stream_execs, mut replay_execs) = (0u64, 0u64);
    let mut ada_speedup = 0.0f64;
    for method in [Method::Nearest, Method::BiasCorr, Method::AdaRound] {
        let mut secs = [0.0f64; 2];
        let mut weights: Vec<BTreeMap<String, Tensor>> = Vec::new();
        for (mi, replay) in [(0usize, false), (1usize, true)] {
            let cfg = PipelineConfig {
                method,
                bits: 4,
                calib_n: o.calib_n,
                col_budget: 512,
                adaround: AdaRoundConfig { iters: o.iters, ..Default::default() },
                replay_sampler: replay,
                ..Default::default()
            };
            let pipe = Pipeline::new(&model, cfg, None);
            let sw = Stopwatch::start();
            let qm = pipe.quantize(&calib, &mut Rng::new(7))?;
            secs[mi] = sw.secs();
            let mode = if replay { "replay" } else { "streaming" };
            println!(
                "{:<12} {:<10} {:>9.2}s {:>16}",
                method.name(),
                mode,
                secs[mi],
                qm.layer_execs
            );
            if replay {
                replay_execs = qm.layer_execs;
            } else {
                stream_execs = qm.layer_execs;
            }
            let mut e = BTreeMap::new();
            e.insert(
                "name".to_string(),
                Json::Str(format!("quantize {} {mode} d{}", method.name(), o.depth)),
            );
            e.insert("mean_ms".to_string(), Json::Num(secs[mi] * 1e3));
            e.insert("layer_execs".to_string(), Json::Num(qm.layer_execs as f64));
            results.push(Json::Obj(e));
            weights.push(qm.weight_overrides);
        }
        if weights[0] != weights[1] {
            bail!("streaming and replay samplers disagree for {}", method.name());
        }
        if method == Method::AdaRound {
            ada_speedup = secs[1] / secs[0].max(1e-9);
        }
    }
    println!(
        "layer-forwards: streaming {stream_execs} vs replay {replay_execs} \
         ({:.1}x fewer); adaround pipeline speedup {ada_speedup:.2}x",
        replay_execs as f64 / stream_execs.max(1) as f64
    );

    // transformer entries: same streaming-vs-replay equivalence gate on
    // the branchy multi-consumer attention subgraph (the stress case for
    // the activation store's liveness tracking)
    let tdepth = 2;
    let tmodel = Model::synthetic_transformer(tdepth, 2, 16, 8, &mut Rng::new(5));
    let tcalib = synthetic_tokens(
        o.calib_n.min(128),
        8,
        crate::nn::graph::TRANSFORMER_VOCAB,
        &mut Rng::new(9),
    );
    for method in [Method::Nearest, Method::AdaRound] {
        let mut weights: Vec<BTreeMap<String, Tensor>> = Vec::new();
        for replay in [false, true] {
            let cfg = PipelineConfig {
                method,
                bits: 4,
                calib_n: tcalib.shape[0],
                col_budget: 512,
                adaround: AdaRoundConfig { iters: o.iters, ..Default::default() },
                replay_sampler: replay,
                ..Default::default()
            };
            let pipe = Pipeline::new(&tmodel, cfg, None);
            let sw = Stopwatch::start();
            let qm = pipe.quantize(&tcalib, &mut Rng::new(7))?;
            let secs = sw.secs();
            let mode = if replay { "replay" } else { "streaming" };
            println!(
                "{:<12} {:<10} {:>9.2}s {:>16}  (transformer d{tdepth})",
                method.name(),
                mode,
                secs,
                qm.layer_execs
            );
            let mut e = BTreeMap::new();
            e.insert(
                "name".to_string(),
                Json::Str(format!("quantize {} {mode} tfm d{tdepth}", method.name())),
            );
            e.insert("mean_ms".to_string(), Json::Num(secs * 1e3));
            e.insert("layer_execs".to_string(), Json::Num(qm.layer_execs as f64));
            results.push(Json::Obj(e));
            weights.push(qm.weight_overrides);
        }
        if weights[0] != weights[1] {
            bail!(
                "streaming and replay samplers disagree for {} on the transformer",
                method.name()
            );
        }
    }

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("pipeline".to_string()));
    root.insert("threads".to_string(), Json::Num(parallel::num_threads() as f64));
    root.insert("depth".to_string(), Json::Num(o.depth as f64));
    root.insert("streaming_layer_execs".to_string(), Json::Num(stream_execs as f64));
    root.insert("replay_layer_execs".to_string(), Json::Num(replay_execs as f64));
    root.insert("adaround_replay_over_streaming".to_string(), Json::Num(ada_speedup));
    root.insert("results".to_string(), Json::Arr(results));
    std::fs::write(&o.out, Json::Obj(root).to_string_pretty())?;
    println!("(wrote {})", o.out);
    Ok(())
}

/// `quantize-bench` subcommand: CLI front-end of [`run_quantize_bench`].
pub fn cmd_quantize_bench(args: &Args) -> Result<()> {
    let d = QuantizeBenchOpts::default();
    let o = QuantizeBenchOpts {
        depth: args.usize("depth", d.depth)?,
        ch: args.usize("ch", d.ch)?,
        calib_n: args.usize("calib-n", d.calib_n)?,
        iters: args.usize("iters", d.iters)?,
        out: args.str("out", &d.out),
    };
    run_quantize_bench(&o)
}

/// `sweep`: bits x method accuracy grid for one model.
pub fn cmd_sweep(args: &Args) -> Result<()> {
    let ctx = Ctx::load(args)?;
    let name = args.str("model", "micro18");
    let model = ctx.model(&name)?;
    let (calib, _) = ctx.calib(&model)?;
    let val = ctx.val(&model)?;
    let bits_list: Vec<u32> = args
        .str("bits-list", "8,4,3,2")
        .split(',')
        .map(|b| b.parse().unwrap_or(4))
        .collect();
    let methods: Vec<&str> = args
        .flags
        .get("methods")
        .map(|s| s.as_str())
        .unwrap_or("nearest,biascorr,adaround")
        .split(',')
        .collect::<Vec<_>>();
    let fp = ctx.metric(&model, &val.0, &val.1, &ForwardOptions::default());
    println!("== sweep {name} (fp32 {fp:.2}%) ==");
    print!("{:>6}", "bits");
    for m in &methods {
        print!(" {m:>12}");
    }
    println!();
    for &bits in &bits_list {
        print!("{bits:>6}");
        for m in &methods {
            let mut cfg = config_from_args(args)?;
            cfg.method = crate::coordinator::Method::parse(m)
                .ok_or_else(|| anyhow::anyhow!("bad method {m}"))?;
            cfg.bits = bits;
            let pipe = Pipeline::new(&model, cfg, Some(&ctx.rt));
            let qm = pipe.quantize(&calib, &mut Rng::new(77))?;
            let acc = ctx.metric(&pipe.work, &val.0, &val.1, &qm.opts());
            print!(" {acc:>11.2}%");
        }
        println!();
    }
    Ok(())
}

/// Native vs PJRT inference-engine comparison on micro18 (the qlinear
/// artifacts exist for this model): same quantized weights, same numbers,
/// different engines — reported with throughput.
pub fn cmd_bench_engine(args: &Args) -> Result<()> {
    let ctx = Ctx::load(args)?;
    let name = args.str("model", "micro18");
    let model = ctx.model(&name)?;
    let (calib, _) = ctx.calib(&model)?;
    let imgs = ctx.rt.manifest.json.usize_of("qlinear_imgs").unwrap_or(32);
    let per: usize = calib.shape[1..].iter().product();
    let x = Tensor::from_vec(
        &[imgs, calib.shape[1], calib.shape[2], calib.shape[3]],
        calib.data[..imgs * per].to_vec(),
    );

    // --- native engine ---
    let sw = Stopwatch::start();
    let reps = args.usize("reps", 5)?;
    let mut y_native = Tensor::zeros(&[1]);
    for _ in 0..reps {
        y_native = model.forward(&x, &ForwardOptions::default());
    }
    let native_s = sw.secs() / reps as f64;

    // --- PJRT engine: run each conv/dense as a qlinear artifact with the
    //     nearest-rounding mask (R from frac >= 0.5) ---
    let sw = Stopwatch::start();
    let mut y_pjrt = Tensor::zeros(&[1]);
    for _ in 0..reps {
        y_pjrt = forward_pjrt(&ctx, &model, &x)?;
    }
    let pjrt_s = sw.secs() / reps as f64;

    let diff = y_native
        .data
        .iter()
        .zip(&y_pjrt.data)
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0, f64::max);
    println!("engine comparison on {name} ({imgs} images, {reps} reps):");
    println!("  native {:.1} ms/batch   {:.1} img/s", native_s * 1e3, imgs as f64 / native_s);
    println!("  pjrt   {:.1} ms/batch   {:.1} img/s", pjrt_s * 1e3, imgs as f64 / pjrt_s);
    println!("  max |Δlogit| = {diff:.2e}  (note: pjrt path uses FP32-equivalent");
    println!("  R=nearest with a huge scale, so outputs must match closely)");
    Ok(())
}

/// Full-model forward where every conv/dense runs through its qlinear HLO
/// executable (im2col on the rust side). Uses an effectively-FP32 grid so
/// the comparison isolates engine overhead, not quantization error.
fn forward_pjrt(ctx: &Ctx, model: &crate::nn::Model, x: &Tensor) -> Result<Tensor> {
    use crate::nn::Op;
    use std::collections::BTreeMap;
    let mut vals: BTreeMap<&str, Tensor> = BTreeMap::new();
    for nd in &model.nodes {
        let out = match &nd.op {
            Op::Input => x.clone(),
            Op::Conv { k, stride, pad, groups, relu } => {
                let inp = &vals[nd.inputs[0].as_str()];
                let geom = nd.geom().unwrap();
                let p = Conv2dParams { k: *k, stride: *stride, pad: *pad, groups: *groups };
                let w4 = model.weight(&nd.id);
                let bias = model.bias(&nd.id);
                let (n_img, h, w_dim) = (inp.shape[0], inp.shape[2], inp.shape[3]);
                let ho = crate::tensor::conv::out_size(h, *k, *stride, *pad);
                let wo = crate::tensor::conv::out_size(w_dim, *k, *stride, *pad);
                let npos = n_img * ho * wo;
                let exec = ctx.rt.qlinear_exec(geom.rows, geom.cols, npos)?;
                let og = geom.rows;
                let mut out = Tensor::zeros(&[n_img, nd.cout, ho, wo]);
                for g in 0..*groups {
                    let cols = im2col(inp, g, p);
                    let wg = Tensor::from_vec(
                        &[og, geom.cols],
                        w4.data[g * og * geom.cols..(g + 1) * og * geom.cols].to_vec(),
                    );
                    // FP32-equivalent quantization: one giant scale, R=nearest
                    let s = Tensor::full(&[og, 1], 1e-6);
                    let r = wg.map(|v| {
                        let z = v / 1e-6;
                        (z - z.floor() >= 0.5) as u8 as f32
                    });
                    let b = Tensor::from_vec(&[og, 1],
                        bias.data[g * og..(g + 1) * og].to_vec());
                    let y = exec.run(&wg, &r, &s, &b, &cols, -8.4e6, 8.4e6)?;
                    // scatter [og, npos] -> NCHW
                    let hw = ho * wo;
                    for oi in 0..og {
                        let oc = g * og + oi;
                        for ni in 0..n_img {
                            let dst = &mut out.data
                                [((ni * nd.cout + oc) * hw)..((ni * nd.cout + oc + 1) * hw)];
                            dst.copy_from_slice(&y.data[oi * npos + ni * hw..oi * npos + (ni + 1) * hw]);
                        }
                    }
                }
                if *relu {
                    out.relu_inplace();
                }
                out
            }
            Op::Dense { relu } => {
                let inp = &vals[nd.inputs[0].as_str()];
                let w = model.weight(&nd.id);
                let b = model.bias(&nd.id);
                let mut y = crate::tensor::matmul_bt(inp, w);
                for r in 0..y.rows() {
                    for (v, bb) in y.row_mut(r).iter_mut().zip(&b.data) {
                        *v += bb;
                    }
                }
                if *relu {
                    y.relu_inplace();
                }
                y
            }
            Op::Add { relu } => {
                let mut y = vals[nd.inputs[0].as_str()].add(&vals[nd.inputs[1].as_str()]);
                if *relu {
                    y.relu_inplace();
                }
                y
            }
            Op::Relu => vals[nd.inputs[0].as_str()].relu(),
            Op::AvgPool { k, stride } => {
                crate::tensor::pool::avgpool2d(&vals[nd.inputs[0].as_str()], *k, *stride)
            }
            Op::GPool => crate::tensor::pool::global_avgpool(&vals[nd.inputs[0].as_str()]),
            Op::Upsample => crate::tensor::pool::upsample2x(&vals[nd.inputs[0].as_str()]),
            Op::Concat => {
                let ins: Vec<&Tensor> = nd.inputs.iter().map(|i| &vals[i.as_str()]).collect();
                crate::tensor::pool::concat_channels(&ins)
            }
            Op::LayerNorm | Op::Softmax { .. } | Op::MatMul { .. } | Op::Gelu | Op::Embedding => {
                bail!(
                    "bench-engine: no qlinear artifacts for transformer op '{:?}' (node '{}')",
                    nd.op,
                    nd.id
                )
            }
        };
        vals.insert(nd.id.as_str(), out);
    }
    let last = model.nodes.last().unwrap().id.as_str();
    Ok(vals.remove(last).unwrap())
}
