//! Paper-figure drivers: `adaround fig <n>` prints the data series each
//! figure plots (CSV-ish, ready for any plotting tool).

use anyhow::{bail, Result};

use crate::adaround::relax;
use crate::adaround::{LayerProblem, NativeOptimizer, RoundingOptimizer};
use crate::coordinator::calib::sample_layer;
use crate::coordinator::Method;
use crate::nn::ForwardOptions;
use crate::quant::{fake_quant, rounding_mask, QuantGrid, RoundingMode};
use crate::qubo::QuboProblem;
use crate::tensor::Tensor;
use crate::util::cli::Args;
use crate::util::stats::{pearson, spearman};
use crate::util::Rng;

use super::common::{config_from_args, sensor_layer, Ctx};

pub fn cmd_fig(args: &Args) -> Result<()> {
    let n: usize = args
        .positional
        .first()
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0);
    let ctx = Ctx::load(args)?;
    match n {
        1 => fig1(&ctx, args),
        2 => fig2(),
        3 => fig3(&ctx, args),
        4 => fig4(&ctx, args),
        _ => bail!("adaround fig <1..4>"),
    }
}

/// Fig 1: QUBO cost (eq. 13 with the local Gram H) vs validation accuracy
/// over stochastic roundings of the first layer.
fn fig1(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.model(&args.str("model", "micro18"))?;
    let (calib, _) = ctx.calib(&model)?;
    let val = ctx.val(&model)?;
    let cfg = config_from_args(args)?;
    let draws = args.usize("stochastic-n", 100)?;

    let sensor = sensor_layer(&model, args);
    let node = model.node(&sensor[0]).unwrap().clone();
    let geom = node.geom().unwrap();
    let w4 = model.weight(&node.id).clone();
    let w = Tensor::from_vec(&[w4.shape[0], geom.cols], w4.data.clone());
    let grid = QuantGrid::fit(&w, cfg.bits, cfg.grid, false, None);

    // local Gram from FP32 calibration activations
    let mut rng = Rng::new(7);
    let sample = sample_layer(&model, &node, &calib, &ForwardOptions::default(),
                              cfg.col_budget, 64, &mut rng);
    let h = crate::qubo::gram(&sample.x_fp[0]);
    let probs: Vec<QuboProblem> = (0..w.rows())
        .map(|r| QuboProblem::from_row(w.row(r), &grid, r, &h))
        .collect();

    println!("== Fig 1: QUBO cost (eq. 13) vs accuracy, layer {}, {} draws ==", sensor[0], draws);
    println!("cost,accuracy");
    let mut costs = Vec::new();
    let mut accs = Vec::new();
    for d in 0..draws {
        let mut rng = Rng::new(9000 + d as u64);
        let mask = rounding_mask(&w, &grid, RoundingMode::Stochastic, &mut rng);
        let cost: f64 = probs
            .iter()
            .enumerate()
            .map(|(r, p)| {
                let row: Vec<u8> = mask.row(r).iter().map(|&v| v as u8).collect();
                p.eval(&row)
            })
            .sum();
        let wq = fake_quant(&w, &mask, &grid);
        let mut ov = std::collections::BTreeMap::new();
        ov.insert(node.id.clone(), Tensor::from_vec(&w4.shape, wq.data));
        let opts = ForwardOptions { weight_overrides: Some(&ov), ..Default::default() };
        let acc = ctx.metric(&model, &val.0, &val.1, &opts);
        println!("{cost:.6e},{acc:.2}");
        costs.push(cost);
        accs.push(acc);
    }
    println!("# pearson  r = {:+.3}", pearson(&costs, &accs));
    println!("# spearman r = {:+.3}", spearman(&costs, &accs));
    println!("# (paper shows a clear negative correlation: lower cost -> higher accuracy)");
    Ok(())
}

/// Fig 2: the regularizer 1-|2h-1|^beta for annealed beta values.
pub fn fig2() -> Result<()> {
    let betas = [2.0f32, 4.0, 8.0, 16.0];
    println!("== Fig 2: effect of annealing beta on f_reg ==");
    print!("h");
    for b in betas {
        print!(",beta={b}");
    }
    println!();
    for i in 0..=40 {
        let h = i as f32 / 40.0;
        print!("{h:.3}");
        for b in betas {
            print!(",{:.4}", relax::f_reg_elem(h, b));
        }
        println!();
    }
    Ok(())
}

/// Fig 3: h(V) before (= frac(w/s)) vs after optimization.
fn fig3(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.model(&args.str("model", "micro18"))?;
    let (calib, _) = ctx.calib(&model)?;
    let cfg = config_from_args(args)?;
    // a mid-network layer gives the richest picture
    let layers = model.quant_layers();
    let node = layers[layers.len() / 2].clone();
    let geom = node.geom().unwrap();
    let w4 = model.weight(&node.id).clone();
    let w = Tensor::from_vec(&[w4.shape[0], geom.cols], w4.data.clone());
    let grid = QuantGrid::fit(&w, cfg.bits, cfg.grid, false, None);

    let mut rng = Rng::new(11);
    let sample = sample_layer(&model, &node, &calib, &ForwardOptions::default(),
                              cfg.col_budget, 64, &mut rng);
    let bias = model.bias(&node.id).data.clone();
    let prob = LayerProblem::new(w.clone(), &grid, 0, bias, false);
    let x = &sample.x_fp[0];
    let mut t = crate::tensor::matmul(&w, x);
    let nc = t.cols();
    for r in 0..w.rows() {
        let b = prob.bias[r];
        for v in &mut t.data[r * nc..(r + 1) * nc] {
            *v += b;
        }
    }
    let mut arcfg = cfg.adaround;
    arcfg.iters = args.usize("iters", 800)?;
    let res = NativeOptimizer.optimize(&prob, x, &t, &arcfg, &mut rng)?;

    println!("== Fig 3: h(V) before vs after optimization, layer {} ==", node.id);
    println!("h_before,h_after");
    let v0 = prob.init_v();
    let mut quad = [0usize; 4]; // [stay-down, stay-up, flip-up, flip-down]
    for i in 0..v0.numel() {
        let hb = relax::rect_sigmoid(v0.data[i]);
        let ha = relax::rect_sigmoid(res.v.data[i]);
        if i % ((v0.numel() / 300).max(1)) == 0 {
            println!("{hb:.4},{ha:.4}");
        }
        match (hb >= 0.5, ha >= 0.5) {
            (false, false) => quad[0] += 1,
            (true, true) => quad[1] += 1,
            (false, true) => quad[2] += 1,
            (true, false) => quad[3] += 1,
        }
    }
    let n = v0.numel();
    println!("# quadrants: stay-down {} stay-up {} FLIP-up {} FLIP-down {} (of {n})",
             quad[0], quad[1], quad[2], quad[3]);
    let binary = res
        .v
        .data
        .iter()
        .filter(|&&v| {
            let h = relax::rect_sigmoid(v);
            h < 0.05 || h > 0.95
        })
        .count();
    println!("# converged to binary: {:.1}%", 100.0 * binary as f64 / n as f64);
    Ok(())
}

/// Fig 4: #calibration images x dataset domain -> AdaRound accuracy.
fn fig4(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.model(&args.str("model", "micro18"))?;
    let val = ctx.val(&model)?;
    let seeds = ctx.seeds.min(2);
    let counts = [32usize, 64, 128, 256, 512, 1024];
    let sets = [("gabor (training domain)", "calib_gabor"),
                ("checker (shifted domain)", "calib_checker")];
    println!("== Fig 4: calibration-data robustness ({}) ==", model.name);
    println!("{:<26} {}", "images", "accuracy per dataset");
    print!("{:<26}", "n");
    for (label, _) in sets {
        print!(" {label:>26}");
    }
    println!();
    for &n in &counts {
        print!("{n:<26}");
        for (_, ds) in sets {
            let (calib, _) = ctx.rt.manifest.load_dataset(ds)?;
            let mut cfg = config_from_args(args)?;
            cfg.method = Method::AdaRound;
            cfg.calib_n = n;
            let accs = super::common::run_seeds(ctx, &model, &cfg, &calib, &val, seeds)?;
            print!(" {:>26}", crate::util::stats::fmt_mean_std(&accs));
        }
        println!();
    }
    let fp = ctx.metric(&model, &val.0, &val.1, &ForwardOptions::default());
    println!("# fp32 reference: {fp:.2}%");
    Ok(())
}


