//! Shared experiment plumbing: context loading, one-shot quantize+eval,
//! seed sweeps.

use anyhow::Result;

use crate::adaround::AdaRoundConfig;
use crate::coordinator::{Method, Pipeline, PipelineConfig};
use crate::data::take;
use crate::eval::{miou, top1};
use crate::nn::{ForwardOptions, Model};
use crate::quant::GridMethod;
use crate::runtime::Runtime;
use crate::tensor::{IntTensor, Tensor};
use crate::util::cli::Args;
use crate::util::Rng;

/// Everything an experiment needs.
pub struct Ctx {
    pub rt: Runtime,
    pub val_n: usize,
    pub seeds: usize,
}

impl Ctx {
    pub fn load(args: &Args) -> Result<Ctx> {
        let dir = args.str("artifacts", &crate::artifacts_dir());
        Ok(Ctx {
            rt: Runtime::new(&dir)?,
            val_n: args.usize("val-n", 512)?,
            seeds: args.usize("seeds", 3)?,
        })
    }

    pub fn model(&self, name: &str) -> Result<Model> {
        self.rt.manifest.load_model(name)
    }

    /// Calibration set for a model's task (unlabeled use).
    pub fn calib(&self, model: &Model) -> Result<(Tensor, IntTensor)> {
        let ds = if model.task == "seg" { "calib_shapes" } else { "calib_gabor" };
        self.rt.manifest.load_dataset(ds)
    }

    /// Validation set, truncated to `val_n`.
    pub fn val(&self, model: &Model) -> Result<(Tensor, IntTensor)> {
        let ds = if model.task == "seg" { "val_shapes" } else { "val_gabor" };
        let (x, y) = self.rt.manifest.load_dataset(ds)?;
        Ok(take(&x, &y, self.val_n))
    }

    /// Task metric (% top-1 or % mIOU) under the given forward options.
    pub fn metric(
        &self,
        model: &Model,
        x: &Tensor,
        y: &IntTensor,
        opts: &ForwardOptions,
    ) -> f64 {
        if model.task == "seg" {
            miou(model, x, y, opts, 32, 4)
        } else {
            top1(model, x, y, opts, 64)
        }
    }
}

/// Build a PipelineConfig from CLI flags + overrides.
pub fn config_from_args(args: &Args) -> Result<PipelineConfig> {
    let mut cfg = PipelineConfig {
        method: Method::parse(&args.str("method", "adaround"))
            .ok_or_else(|| anyhow::anyhow!("unknown --method"))?,
        bits: args.usize("bits", 2)? as u32,
        grid: GridMethod::parse(&args.str("grid", "mse-w"))
            .ok_or_else(|| anyhow::anyhow!("unknown --grid"))?,
        per_channel: args.bool("per-channel"),
        calib_n: args.usize("calib-n", 256)?,
        ..Default::default()
    };
    if let Some(b) = args.opt("act-bits") {
        cfg.act_bits = Some(b.parse()?);
    }
    if let Some(b) = args.opt("bit-budget") {
        // mixed precision: mean bits per weight the allocator may spend
        cfg.bit_budget = Some(b.parse()?);
    }
    cfg.adaround = AdaRoundConfig {
        iters: args.usize("iters", 800)?,
        lr: args.f32("lr", 1e-2)?,
        lambda: args.f32("lambda", 0.01)?,
        ..Default::default()
    };
    if args.bool("pre-cle") {
        cfg.pre_cle = true;
    }
    if args.bool("replay-sampler") {
        cfg.replay_sampler = true; // O(L²) reference path (A/B verification)
    }
    Ok(cfg)
}

/// Run quantize+evaluate once; returns the task metric (%).
pub fn run_once(
    ctx: &Ctx,
    model: &Model,
    cfg: &PipelineConfig,
    calib: &Tensor,
    val: &(Tensor, IntTensor),
    seed: u64,
) -> Result<f64> {
    let mut rng = Rng::new(seed);
    let pipe = Pipeline::new(model, cfg.clone(), Some(&ctx.rt));
    let qm = pipe.quantize(calib, &mut rng)?;
    // evaluate on the *working* model (CLE-equalized weights for DFQ)
    Ok(ctx.metric(&pipe.work, &val.0, &val.1, &qm.opts()))
}

/// Seed sweep; returns per-seed metrics.
pub fn run_seeds(
    ctx: &Ctx,
    model: &Model,
    cfg: &PipelineConfig,
    calib: &Tensor,
    val: &(Tensor, IntTensor),
    seeds: usize,
) -> Result<Vec<f64>> {
    (0..seeds)
        .map(|s| run_once(ctx, model, cfg, calib, val, 1000 + s as u64))
        .collect()
}

/// The "first layer" of the single-layer experiments (Tables 1/2/10,
/// Figs 1/3). Overridable with --layer: on this testbed the stem
/// (8x27 = 216 weights) is too small to exhibit the paper's single-layer
/// collapse, so tables default to the largest early conv instead —
/// documented in DESIGN.md §1.
pub fn first_layer(model: &Model) -> Vec<String> {
    vec![model.quant_layers()[0].id.clone()]
}

/// Pick the experiment's sensor layer: --layer flag, or the largest conv.
pub fn sensor_layer(model: &Model, args: &Args) -> Vec<String> {
    if let Some(id) = args.opt("layer") {
        return vec![id.to_string()];
    }
    let mut best = (0usize, String::new());
    for nd in model.quant_layers() {
        let g = nd.geom().unwrap();
        let n = g.rows * g.cols * g.groups;
        if n > best.0 {
            best = (n, nd.id.clone());
        }
    }
    vec![best.1]
}

pub fn cmd_models(args: &Args) -> Result<()> {
    let ctx = Ctx::load(args)?;
    println!("{:<14} {:>8} {:>8} {:>10}", "model", "params", "layers", "fp32");
    for name in ctx.rt.manifest.model_names() {
        let m = ctx.model(&name)?;
        let fp = ctx.rt.manifest.fp32_metric(&name).unwrap_or(f64::NAN);
        println!(
            "{:<14} {:>8} {:>8} {:>9.2}%",
            name,
            m.num_params(),
            m.quant_layers().len(),
            fp
        );
    }
    Ok(())
}

/// Pretty-print one table row: label + per-column "mean±std" strings.
pub fn print_row(label: &str, cells: &[String]) {
    print!("{label:<34}");
    for c in cells {
        print!(" {c:>16}");
    }
    println!();
}
