//! Command-line interface: `adaround <subcommand> [flags]`.
//!
//! Subcommands:
//!   models                 list models + FP32 reference metrics
//!   eval                   evaluate FP32 or quantized model
//!   quantize               run the PTQ pipeline once and report accuracy
//!   table <1|2|...|10>     regenerate a paper table
//!   fig <1|2|3|4>          regenerate a paper figure's data
//!   bench-engine           native vs PJRT inference engine comparison
//!   serve                  HTTP serving front-end (/v1/infer, /metrics)
//!   serve-bench            f32 fake-quant vs int8 serving engine
//!   quantize-bench         streaming vs replay calibration pipeline bench
//!   bench-diff             compare two BENCH_*.json files (CI perf gate)

pub mod common;
pub mod figs;
pub mod quantize;
pub mod serve;
pub mod tables;

use anyhow::{bail, Result};

use crate::util::cli::Args;

pub const USAGE: &str = "\
adaround — AdaRound post-training quantization framework (ICML 2020 repro)

USAGE:
  adaround models                               list models
  adaround eval     --model M [--bits B ...]    evaluate
  adaround quantize --model M --method X        quantize + evaluate
  adaround quantize --synthetic-transformer [--depth D] [--heads H]
                    [--d-model D] [--seq S] [--assert-beats-nearest]
                    artifact-free transformer PTQ (per-head grids; reports
                    per-layer recon-MSE instead of a task metric)
  adaround table N  [--seeds S] [--val-n V]     regenerate paper Table N
  adaround fig N                                regenerate paper Figure N data
  adaround sweep    --model M --bits-list 8,4,2  bits x method accuracy grid
  adaround bench-engine --model micro18         native vs PJRT engine
  adaround serve    --listen HOST:PORT [--synthetic|--model M]
                    [--quantized B.qtz] [--shards N] [--depth-budget D]
                    [--auth-token T] [--drain-after-secs S]
                    HTTP front-end: POST /v1/infer, GET /metrics (Prometheus),
                    GET /healthz; 429 past the admission budget, graceful
                    drain on SIGTERM/ctrl-c (docs/SERVING.md)
  adaround serve-bench --model M [--quantized B.qtz] [--shards N]
                    int8 engine + sharded batcher (docs/SERVING.md)
  adaround quantize-bench [--depth D] [--calib-n N] [--iters I]
                    O(L) streaming vs O(L²) replay calibration pipeline
  adaround bench-diff A.json B.json [--tol PCT] perf regression gate (CI)

COMMON FLAGS:
  --artifacts DIR   artifact directory (default: artifacts)
  --model NAME      micro18|micro50|microinc|micromobile|segnet
  --method M        nearest|floor|ceil|stochastic|adaround|adaround-pjrt|
                    ste|hopfield|sigmoid-freg|qubo-cem|qubo-tabu|biascorr|
                    dfq|ocs|omse|attention-round
  --bits B          weight bits (default 4)
  --bit-budget X    mixed precision: mean bits/weight (e.g. 4.5); a
                    sensitivity pre-pass assigns each layer 4 or 8 bits,
                    4-bit layers serve nibble-packed (w4)
  --act-bits B      quantize activations to B bits
  --grid G          minmax|mse-w|mse-out (default mse-w)
  --per-channel     per-channel weight scales
  --calib-n N       calibration images (default 256)
  --iters N         AdaRound iterations (default 800)
  --seeds S         seeds per table cell
  --val-n V         validation images per evaluation (default 512)
  --first-layer     quantize only the first layer
  --replay-sampler  O(L²) full-replay calibration sampler (A/B reference;
                    default is the bit-identical O(L) streaming store)
";

pub fn run(args: Args) -> Result<()> {
    match args.subcommand.as_str() {
        "models" => common::cmd_models(&args),
        "eval" => quantize::cmd_eval(&args),
        "quantize" => quantize::cmd_quantize(&args),
        "table" => tables::cmd_table(&args),
        "fig" => figs::cmd_fig(&args),
        "bench-engine" => quantize::cmd_bench_engine(&args),
        "quantize-bench" => quantize::cmd_quantize_bench(&args),
        "serve" => serve::cmd_serve(&args),
        "serve-bench" => serve::cmd_serve_bench(&args),
        "bench-diff" => serve::cmd_bench_diff(&args),
        "sweep" => quantize::cmd_sweep(&args),
        "" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}
