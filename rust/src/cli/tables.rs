//! Paper-table drivers: `adaround table <n>` regenerates the rows of the
//! corresponding table in the paper on this testbed's model zoo
//! (substitutions documented in DESIGN.md §1; expected *shapes* in §4).

use anyhow::{bail, Result};

use crate::coordinator::{Method, PipelineConfig};
use crate::data::take;
use crate::nn::ForwardOptions;
use crate::quant::GridMethod;
use crate::tensor::Tensor;
use crate::util::cli::Args;
use crate::util::stats::fmt_mean_std;
use crate::util::Rng;

use super::common::{config_from_args, print_row, run_seeds, sensor_layer, Ctx};

pub fn cmd_table(args: &Args) -> Result<()> {
    let n: usize = args
        .positional
        .first()
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0);
    let ctx = Ctx::load(args)?;
    match n {
        1 => table1(&ctx, args),
        2 => table2(&ctx, args),
        3 => table3(&ctx, args),
        4 => table4(&ctx, args),
        5 => table5(&ctx, args),
        6 => table6(&ctx, args),
        7 => table7(&ctx, args),
        8 => table8(&ctx, args),
        9 => table9(&ctx, args),
        10 => table10(&ctx, args),
        _ => bail!("adaround table <1..10>"),
    }
}

fn base_cfg(args: &Args) -> Result<PipelineConfig> {
    config_from_args(args)
}

/// Table 1: nearest / ceil / floor / stochastic x N, first layer @ 4 bits.
fn table1(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.model(&args.str("model", "micro18"))?;
    let (calib, _) = ctx.calib(&model)?;
    let val = ctx.val(&model)?;
    let n_stoch = args.usize("stochastic-n", 100)?;
    let mut cfg = base_cfg(args)?;
    let sensor = sensor_layer(&model, args);
    cfg.only_layers = Some(sensor.clone());

    println!("== Table 1: rounding schemes, layer {} of {} @ {}-bit ==",
             sensor[0], model.name, cfg.bits);
    let fp = ctx.metric(&model, &val.0, &val.1, &ForwardOptions::default());
    println!("fp32 reference: {fp:.2}%");
    for method in [Method::Nearest, Method::Ceil, Method::Floor] {
        cfg.method = method;
        let accs = run_seeds(ctx, &model, &cfg, &calib, &val, 1)?;
        print_row(method.name(), &[format!("{:.2}", accs[0])]);
    }
    cfg.method = Method::Stochastic;
    let mut accs = Vec::new();
    for s in 0..n_stoch {
        let acc = super::common::run_once(ctx, &model, &cfg, &calib, &val, 5000 + s as u64)?;
        accs.push(acc);
        if (s + 1) % 20 == 0 {
            crate::info!("stochastic {}/{n_stoch}", s + 1);
        }
    }
    let best = accs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    print_row(&format!("stochastic ({n_stoch} draws)"), &[fmt_mean_std(&accs)]);
    print_row("stochastic (best)", &[format!("{best:.2}")]);
    Ok(())
}

/// Table 2: task-loss QUBO vs local-MSE QUBO vs continuous relaxation.
fn table2(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.model(&args.str("model", "micro18"))?;
    let (calib, calib_y) = ctx.calib(&model)?;
    let val = ctx.val(&model)?;
    let seeds = ctx.seeds.min(3);
    let mut cfg = base_cfg(args)?;
    println!("== Table 2: from task loss to local loss ({}) ==", model.name);
    println!("{:<34} {:>16} {:>16}", "rounding", "first layer", "all layers");

    // nearest
    let sensor = sensor_layer(&model, args);
    cfg.method = Method::Nearest;
    cfg.only_layers = Some(sensor.clone());
    let f = run_seeds(ctx, &model, &cfg, &calib, &val, 1)?;
    cfg.only_layers = None;
    let a = run_seeds(ctx, &model, &cfg, &calib, &val, 1)?;
    print_row("nearest", &[fmt_mean_std(&f), fmt_mean_std(&a)]);

    // task-loss QUBO: CEM directly on the task loss (objective (11); the
    // H^(w) Taylor proxy of (13) approximates exactly this — see DESIGN.md)
    let accs: Vec<f64> = (0..seeds)
        .map(|s| task_loss_cem(ctx, &model, &sensor[0], &calib, &calib_y, &val, &cfg,
                               2000 + s as u64))
        .collect::<Result<_>>()?;
    print_row("H task loss (CEM, cf. eq.13)", &[fmt_mean_std(&accs), "N/A".into()]);

    // local MSE QUBO (CEM)
    cfg.method = Method::LocalQuboCem;
    cfg.only_layers = Some(sensor.clone());
    let f = run_seeds(ctx, &model, &cfg, &calib, &val, seeds)?;
    cfg.only_layers = None;
    let a = run_seeds(ctx, &model, &cfg, &calib, &val, seeds)?;
    print_row("local MSE loss (CEM, cf. eq.20)", &[fmt_mean_std(&f), fmt_mean_std(&a)]);

    // continuous relaxation (AdaRound objective, symmetric variant of eq.21)
    cfg.method = Method::AdaRound;
    cfg.asymmetric = false;
    cfg.use_relu = false;
    cfg.only_layers = Some(sensor.clone());
    let f = run_seeds(ctx, &model, &cfg, &calib, &val, seeds)?;
    cfg.only_layers = None;
    let a = run_seeds(ctx, &model, &cfg, &calib, &val, seeds)?;
    print_row("cont. relaxation (cf. eq.21)", &[fmt_mean_std(&f), fmt_mean_std(&a)]);
    Ok(())
}

/// CEM over first-layer roundings scored by the true task loss (CE) on a
/// labeled calibration batch.
fn task_loss_cem(
    ctx: &Ctx,
    model: &crate::nn::Model,
    layer_id: &str,
    calib: &Tensor,
    calib_y: &crate::tensor::IntTensor,
    val: &(Tensor, crate::tensor::IntTensor),
    cfg: &PipelineConfig,
    seed: u64,
) -> Result<f64> {
    use crate::quant::{fake_quant, QuantGrid};
    let node = model.node(layer_id).unwrap().clone();
    let geom = node.geom().unwrap();
    let w4 = model.weight(&node.id).clone();
    let w = Tensor::from_vec(&[w4.shape[0], geom.cols], w4.data.clone());
    let grid = QuantGrid::fit(&w, cfg.bits, GridMethod::MseW, false, None);
    let (bx, by) = take(calib, calib_y, 48);
    let mut rng = Rng::new(seed);

    let ce = |mask: &Tensor| -> f64 {
        let wq = fake_quant(&w, mask, &grid);
        let wq4 = Tensor::from_vec(&w4.shape, wq.data.clone());
        let mut ov = std::collections::BTreeMap::new();
        ov.insert(node.id.clone(), wq4);
        let opts = ForwardOptions { weight_overrides: Some(&ov), ..Default::default() };
        let logits = model.forward(&bx, &opts);
        // mean cross-entropy
        let mut loss = 0.0f64;
        for r in 0..logits.rows() {
            let row = logits.row(r);
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = row.iter().map(|v| (v - mx).exp()).sum();
            let y = by.data[r] as usize;
            loss -= ((row[y] - mx) - z.ln()) as f64;
        }
        loss / logits.rows() as f64
    };

    // CEM over the flattened mask, initialized at stochastic-rounding probs
    let numel = w.numel();
    let mut p: Vec<f64> = (0..numel)
        .map(|i| {
            let r = i / geom.cols;
            let s = grid.scale_for_row(r);
            let frac = (w.data[i] / s - (w.data[i] / s).floor()) as f64;
            frac.clamp(0.05, 0.95)
        })
        .collect();
    let mut best_mask = Tensor::from_vec(
        &w.shape,
        p.iter().map(|&pi| (pi >= 0.5) as u8 as f32).collect(),
    );
    let mut best_cost = ce(&best_mask);
    let (pop, iters, elite) = (16, 22, 4);
    for _ in 0..iters {
        let mut cand: Vec<(f64, Vec<f32>)> = (0..pop)
            .map(|_| {
                let m: Vec<f32> = p.iter().map(|&pi| rng.bernoulli(pi) as u8 as f32).collect();
                let cost = ce(&Tensor::from_vec(&w.shape, m.clone()));
                (cost, m)
            })
            .collect();
        cand.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        if cand[0].0 < best_cost {
            best_cost = cand[0].0;
            best_mask = Tensor::from_vec(&w.shape, cand[0].1.clone());
        }
        for i in 0..numel {
            let mean = cand[..elite].iter().map(|(_, m)| m[i] as f64).sum::<f64>()
                / elite as f64;
            p[i] = (0.4 * p[i] + 0.6 * mean).clamp(0.02, 0.98);
        }
    }
    // evaluate the best mask on the validation set
    use crate::quant::fake_quant as fq;
    let wq = fq(&w, &best_mask, &grid);
    let wq4 = Tensor::from_vec(&w4.shape, wq.data);
    let mut ov = std::collections::BTreeMap::new();
    ov.insert(node.id.clone(), wq4);
    let opts = ForwardOptions { weight_overrides: Some(&ov), ..Default::default() };
    Ok(ctx.metric(model, &val.0, &val.1, &opts))
}

/// Table 3: sigmoid+T-annealing vs sigmoid+f_reg vs rect-sigmoid+f_reg.
fn table3(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.model(&args.str("model", "micro18"))?;
    let (calib, _) = ctx.calib(&model)?;
    let val = ctx.val(&model)?;
    let seeds = ctx.seeds;
    let mut cfg = base_cfg(args)?;
    cfg.asymmetric = false;
    cfg.use_relu = false; // Table 3 optimizes (21)
    println!("== Table 3: design choices for optimizing eq. 21 ({}) ==", model.name);
    println!("{:<34} {:>16} {:>16}", "variant", "first layer", "all layers");
    for (label, method) in [
        ("sigmoid + T annealing", Method::Hopfield),
        ("sigmoid + f_reg", Method::SigmoidFreg),
        ("rect. sigmoid + f_reg", Method::AdaRound),
    ] {
        cfg.method = method;
        cfg.only_layers = Some(sensor_layer(&model, args));
        let f = run_seeds(ctx, &model, &cfg, &calib, &val, seeds)?;
        cfg.only_layers = None;
        let a = run_seeds(ctx, &model, &cfg, &calib, &val, seeds)?;
        print_row(label, &[fmt_mean_std(&f), fmt_mean_std(&a)]);
    }
    Ok(())
}

/// Table 4: layer-wise vs asymmetric vs asymmetric + ReLU.
fn table4(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.model(&args.str("model", "micro18"))?;
    let (calib, _) = ctx.calib(&model)?;
    let val = ctx.val(&model)?;
    let seeds = ctx.seeds;
    let mut cfg = base_cfg(args)?;
    cfg.method = Method::AdaRound;
    println!("== Table 4: reconstruction objective ablation ({}) ==", model.name);
    for (label, asym, relu) in [
        ("layer-wise (eq. 21)", false, false),
        ("asymmetric", true, false),
        ("asymmetric + ReLU (eq. 25)", true, true),
    ] {
        cfg.asymmetric = asym;
        cfg.use_relu = relu;
        let a = run_seeds(ctx, &model, &cfg, &calib, &val, seeds)?;
        print_row(label, &[fmt_mean_std(&a)]);
    }
    Ok(())
}

/// Table 5: nearest vs STE vs AdaRound.
fn table5(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.model(&args.str("model", "micro18"))?;
    let (calib, _) = ctx.calib(&model)?;
    let val = ctx.val(&model)?;
    let seeds = ctx.seeds;
    let mut cfg = base_cfg(args)?;
    println!("== Table 5: STE vs AdaRound ({}) ==", model.name);
    for (label, method) in [
        ("nearest", Method::Nearest),
        ("STE", Method::Ste),
        ("AdaRound", Method::AdaRound),
    ] {
        cfg.method = method;
        let s = if method == Method::Nearest { 1 } else { seeds };
        let a = run_seeds(ctx, &model, &cfg, &calib, &val, s)?;
        print_row(label, &[fmt_mean_std(&a)]);
    }
    Ok(())
}

/// Table 6: quantization-grid choice x {nearest, AdaRound}.
fn table6(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.model(&args.str("model", "micro18"))?;
    let (calib, _) = ctx.calib(&model)?;
    let val = ctx.val(&model)?;
    let seeds = ctx.seeds;
    let mut cfg = base_cfg(args)?;
    println!("== Table 6: influence of the quantization grid ({}) ==", model.name);
    println!("{:<34} {:>16} {:>16}", "grid", "nearest", "AdaRound");
    for (label, grid) in [
        ("min-max", GridMethod::MinMax),
        ("||W - W^||_F^2 (mse-w)", GridMethod::MseW),
        ("||Wx - W^x^||_F^2 (mse-out)", GridMethod::MseOut),
    ] {
        cfg.grid = grid;
        cfg.method = Method::Nearest;
        let near = run_seeds(ctx, &model, &cfg, &calib, &val, 1)?;
        cfg.method = Method::AdaRound;
        let ada = run_seeds(ctx, &model, &cfg, &calib, &val, seeds)?;
        print_row(label, &[fmt_mean_std(&near), fmt_mean_std(&ada)]);
    }
    Ok(())
}

/// Table 7: literature comparison across the model zoo.
fn table7(ctx: &Ctx, args: &Args) -> Result<()> {
    let models_arg = args.str("models", "micro18,micro50,microinc,micromobile");
    let models: Vec<&str> = models_arg.split(',').collect();
    let seeds = ctx.seeds.min(2);
    println!("== Table 7: post-training quantization comparison (top-1 %) ==");
    print!("{:<30} {:>6}", "method", "W/A");
    for m in &models {
        print!(" {m:>16}");
    }
    println!();
    // FP32 reference
    print!("{:<30} {:>6}", "full precision", "32/32");
    for m in &models {
        let model = ctx.model(m)?;
        let val = ctx.val(&model)?;
        let fp = ctx.metric(&model, &val.0, &val.1, &ForwardOptions::default());
        print!(" {fp:>16.2}");
    }
    println!();

    let rows: Vec<(&str, Method, &str, Option<u32>)> = vec![
        ("nearest", Method::Nearest, "2/32", None),
        ("OMSE (per-channel)", Method::Omse, "2*/32", None),
        ("OCS", Method::Ocs, "2/32", None),
        ("AdaRound", Method::AdaRound, "2/32", None),
        ("DFQ (our impl.)", Method::Dfq, "2/8", Some(8)),
        ("bias corr", Method::BiasCorr, "2/8", Some(8)),
        ("AdaRound w/ act quant", Method::AdaRound, "2/8", Some(8)),
    ];
    for (label, method, wa, act) in rows {
        print!("{label:<30} {wa:>6}");
        for m in &models {
            let model = ctx.model(m)?;
            let (calib, _) = ctx.calib(&model)?;
            let val = ctx.val(&model)?;
            let mut cfg = base_cfg(args)?;
            cfg.method = method;
            cfg.act_bits = act;
            // paper footnote: CLE preprocessing for the MobilenetV2 analog
            cfg.pre_cle = *m == "micromobile" && method == Method::AdaRound;
            let s = if matches!(method, Method::AdaRound) { seeds } else { 1 };
            let accs = run_seeds(ctx, &model, &cfg, &calib, &val, s)?;
            print!(" {:>16}", fmt_mean_std(&accs));
        }
        println!();
    }
    Ok(())
}

/// Table 8: nearest vs bias correction vs AdaRound.
fn table8(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.model(&args.str("model", "micro18"))?;
    let (calib, _) = ctx.calib(&model)?;
    let val = ctx.val(&model)?;
    let seeds = ctx.seeds;
    let mut cfg = base_cfg(args)?;
    println!("== Table 8: AdaRound vs empirical bias correction ({}) ==", model.name);
    for (label, method) in [
        ("nearest", Method::Nearest),
        ("bias correction", Method::BiasCorr),
        ("AdaRound", Method::AdaRound),
    ] {
        cfg.method = method;
        let s = if method == Method::AdaRound { seeds } else { 1 };
        let a = run_seeds(ctx, &model, &cfg, &calib, &val, s)?;
        print_row(label, &[fmt_mean_std(&a)]);
    }
    Ok(())
}

/// Table 9: semantic segmentation (segnet / shapes, mIOU).
fn table9(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.model("segnet")?;
    let (calib, _) = ctx.calib(&model)?;
    let val = ctx.val(&model)?;
    let seeds = ctx.seeds.min(2);
    println!("== Table 9: segmentation ({} on shapes, mIOU %) ==", model.name);
    let fp = ctx.metric(&model, &val.0, &val.1, &ForwardOptions::default());
    print_row("full precision (32/32)", &[format!("{fp:.2}")]);

    // W2 is this testbed's collapse regime (DESIGN.md §1)
    let rows: Vec<(&str, Method, u32, Option<u32>, usize)> = vec![
        ("DFQ (our impl., 8/8)", Method::Dfq, 8, Some(8), 1),
        ("nearest (2/8)", Method::Nearest, 2, Some(8), 1),
        ("DFQ (our impl., 2/8)", Method::Dfq, 2, Some(8), 1),
        ("AdaRound (2/32)", Method::AdaRound, 2, None, seeds),
        ("AdaRound w/ act quant (2/8)", Method::AdaRound, 2, Some(8), seeds),
    ];
    for (label, method, bits, act, s) in rows {
        let mut cfg = base_cfg(args)?;
        cfg.method = method;
        cfg.bits = bits;
        cfg.act_bits = act;
        let a = run_seeds(ctx, &model, &cfg, &calib, &val, s)?;
        print_row(label, &[fmt_mean_std(&a)]);
    }
    Ok(())
}

/// Table 10 (appendix): CEM vs tabu-search QUBO solver, first layer.
fn table10(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.model(&args.str("model", "micro18"))?;
    let (calib, _) = ctx.calib(&model)?;
    let val = ctx.val(&model)?;
    let seeds = ctx.seeds;
    let mut cfg = base_cfg(args)?;
    let sensor = sensor_layer(&model, args);
    cfg.only_layers = Some(sensor.clone());
    println!("== Table 10: QUBO solvers, layer {} of {} ==", sensor[0], model.name);
    for (label, method, s) in [
        ("nearest", Method::Nearest, 1),
        ("cross-entropy method", Method::LocalQuboCem, seeds),
        ("tabu search (qbsolv analog)", Method::LocalQuboTabu, seeds),
    ] {
        cfg.method = method;
        let a = run_seeds(ctx, &model, &cfg, &calib, &val, s)?;
        print_row(label, &[fmt_mean_std(&a)]);
    }
    Ok(())
}

/// Exposed for the bench harness: run one named table quickly.
pub fn run_table_quick(ctx: &Ctx, n: usize) -> Result<()> {
    let args = Args::parse(
        vec![format!("table"), format!("{n}"), "--seeds".into(), "1".into(),
             "--val-n".into(), "64".into(), "--iters".into(), "60".into(),
             "--calib-n".into(), "32".into(), "--stochastic-n".into(), "3".into()]
            .into_iter(),
    );
    match n {
        1 => table1(ctx, &args),
        3 => table3(ctx, &args),
        4 => table4(ctx, &args),
        5 => table5(ctx, &args),
        6 => table6(ctx, &args),
        8 => table8(ctx, &args),
        10 => table10(ctx, &args),
        _ => bail!("quick table {n} unsupported"),
    }
}
