//! PJRT runtime: load AOT HLO-text artifacts and execute them from rust.
//!
//! The interchange format is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): jax >= 0.5 serialized protos carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids and round-trips cleanly.
//!
//! [`Runtime`] owns one CPU `PjRtClient` and a lazily-populated cache of
//! compiled executables keyed by shape bucket, so each artifact is
//! compiled exactly once per process.
//!
//! The XLA-backed implementation is behind the `pjrt` cargo feature (it
//! needs the vendored `xla` crate, which the offline build environment
//! does not ship). Without the feature, [`stub`] provides the same public
//! API: the manifest loads normally so models/datasets stay usable, and
//! the executors return an error at call time — every caller already
//! handles artifact-less operation gracefully.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod exec;
pub mod manifest;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

#[cfg(feature = "pjrt")]
pub use client::Runtime;
#[cfg(feature = "pjrt")]
pub use exec::{QLinearExec, StepExec, StepState};
pub use manifest::{ExecSpec, Manifest};
#[cfg(not(feature = "pjrt"))]
pub use stub::{QLinearExec, Runtime, StepExec, StepState};
