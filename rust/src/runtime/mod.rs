//! PJRT runtime: load AOT HLO-text artifacts and execute them from rust.
//!
//! The interchange format is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): jax >= 0.5 serialized protos carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids and round-trips cleanly.
//!
//! [`Runtime`] owns one CPU `PjRtClient` and a lazily-populated cache of
//! compiled executables keyed by shape bucket, so each artifact is
//! compiled exactly once per process.

pub mod client;
pub mod exec;
pub mod manifest;

pub use client::Runtime;
pub use exec::{QLinearExec, StepExec, StepState};
pub use manifest::{ExecSpec, Manifest};
