//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::io::read_qtz;
use crate::nn::Model;
use crate::tensor::{IntTensor, Tensor};
use crate::util::Json;

#[derive(Clone, Debug)]
pub struct ExecSpec {
    pub kind: String,
    pub rows: usize,
    pub cols: usize,
    pub batch: usize,
    pub relu: bool,
    pub file: String,
}

pub struct Manifest {
    pub dir: PathBuf,
    pub json: Json,
    pub executables: Vec<ExecSpec>,
    pub step_batch: usize,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let json = Json::parse(&text)?;
        let mut executables = Vec::new();
        for e in json.req("executables")?.as_arr().ok_or_else(|| anyhow!("bad executables"))? {
            executables.push(ExecSpec {
                kind: e.str_of("kind")?.to_string(),
                rows: e.usize_of("rows")?,
                cols: e.usize_of("cols")?,
                batch: e.usize_of("batch")?,
                relu: e.bool_of("relu")?,
                file: e.str_of("file")?.to_string(),
            });
        }
        let step_batch = json.usize_of("step_batch")?;
        Ok(Manifest { dir, json, executables, step_batch })
    }

    pub fn model_names(&self) -> Vec<String> {
        self.json
            .get("models")
            .and_then(|m| m.as_obj())
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    /// Load a model: IR from the manifest + weights from its .qtz bundle.
    pub fn load_model(&self, name: &str) -> Result<Model> {
        let entry = self
            .json
            .req("models")?
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))?;
        let wfile = self.dir.join(entry.str_of("weights")?);
        let bundle = read_qtz(&wfile)?;
        let mut weights = BTreeMap::new();
        for (k, v) in bundle {
            weights.insert(k, v.as_f32()?.clone());
        }
        Model::from_manifest(name, entry, weights)
    }

    /// FP32 reference metric recorded at training time (top1 or miou).
    pub fn fp32_metric(&self, name: &str) -> Option<f64> {
        let rep = self.json.get("models")?.get(name)?.get("fp32_report")?;
        rep.get("top1").or_else(|| rep.get("miou"))?.as_f64()
    }

    /// Load a dataset bundle: (images [N,3,32,32], labels).
    pub fn load_dataset(&self, name: &str) -> Result<(Tensor, IntTensor)> {
        let entry = self
            .json
            .req("datasets")?
            .get(name)
            .ok_or_else(|| anyhow!("dataset '{name}' not in manifest"))?;
        let file = self.dir.join(entry.str_of("file")?);
        let bundle = read_qtz(&file)?;
        let x = bundle.get("x").ok_or_else(|| anyhow!("no x in {name}"))?.as_f32()?.clone();
        let y = bundle.get("y").ok_or_else(|| anyhow!("no y in {name}"))?.as_i32()?.clone();
        Ok((x, y))
    }

    pub fn find_exec(&self, kind: &str, rows: usize, cols: usize, relu: bool) -> Option<&ExecSpec> {
        self.executables
            .iter()
            .find(|e| e.kind == kind && e.rows == rows && e.cols == cols && e.relu == relu)
    }

    pub fn find_qlinear(&self, rows: usize, cols: usize, batch: usize) -> Option<&ExecSpec> {
        self.executables
            .iter()
            .find(|e| e.kind == "qlinear" && e.rows == rows && e.cols == cols && e.batch == batch)
    }
}
