//! Typed wrappers around the two AOT executables.

use std::rc::Rc;

use anyhow::{anyhow, Result};

use crate::tensor::Tensor;

fn lit2(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(&t.data)
        .reshape(&dims)
        .map_err(|e| anyhow!("literal reshape: {e:?}"))
}

fn scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

fn to_tensor(l: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let v = l.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
    Ok(Tensor::from_vec(shape, v))
}

/// Mutable optimizer state shuttled through the step executable.
pub struct StepState {
    pub v: Tensor,
    pub m: Tensor,
    pub v2: Tensor,
    pub t: usize,
}

impl StepState {
    pub fn new(v: Tensor) -> StepState {
        let m = Tensor::zeros(&v.shape);
        let v2 = Tensor::zeros(&v.shape);
        StepState { v, m, v2, t: 0 }
    }
}

/// One compiled AdaRound step artifact (fixed rows/cols/batch/relu).
///
/// Signature (python/compile/model.py):
///   (V, m, v2, t, X, T, W, s, b, beta, lam, lr, n, p) -> (V', m', v2', loss, mse)
pub struct StepExec {
    pub exe: Rc<xla::PjRtLoadedExecutable>,
    pub rows: usize,
    pub cols: usize,
    pub batch: usize,
}

impl StepExec {
    /// Run one optimization step; updates `state` in place and returns
    /// (loss, mse).
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &self,
        state: &mut StepState,
        x: &Tensor,
        t_target: &Tensor,
        w: &Tensor,
        s: &Tensor,
        b: &Tensor,
        beta: f32,
        lam: f32,
        lr: f32,
        n: f32,
        p: f32,
    ) -> Result<(f64, f64)> {
        state.t += 1;
        let args = [
            lit2(&state.v)?,
            lit2(&state.m)?,
            lit2(&state.v2)?,
            scalar(state.t as f32),
            lit2(x)?,
            lit2(t_target)?,
            lit2(w)?,
            lit2(s)?,
            lit2(b)?,
            scalar(beta),
            scalar(lam),
            scalar(lr),
            scalar(n),
            scalar(p),
        ];
        let result = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("step execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        anyhow::ensure!(tuple.len() == 5, "expected 5 outputs, got {}", tuple.len());
        let shape = [self.rows, self.cols];
        state.v = to_tensor(&tuple[0], &shape)?;
        state.m = to_tensor(&tuple[1], &shape)?;
        state.v2 = to_tensor(&tuple[2], &shape)?;
        let loss = tuple[3].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0] as f64;
        let mse = tuple[4].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0] as f64;
        Ok((loss, mse))
    }
}

/// One compiled quantized-matmul inference artifact.
///
/// Signature: (W, R, s, b, X, n, p) -> Y [rows, batch]
pub struct QLinearExec {
    pub exe: Rc<xla::PjRtLoadedExecutable>,
    pub rows: usize,
    pub cols: usize,
    pub batch: usize,
}

impl QLinearExec {
    pub fn run(
        &self,
        w: &Tensor,
        r: &Tensor,
        s: &Tensor,
        b: &Tensor,
        x: &Tensor,
        n: f32,
        p: f32,
    ) -> Result<Tensor> {
        let args = [
            lit2(w)?,
            lit2(r)?,
            lit2(s)?,
            lit2(b)?,
            lit2(x)?,
            scalar(n),
            scalar(p),
        ];
        let result = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("qlinear execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        to_tensor(&tuple[0], &[self.rows, self.batch])
    }
}
