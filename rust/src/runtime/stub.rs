//! API-compatible stand-in for the PJRT runtime, compiled when the `pjrt`
//! feature is off (the default: the offline build has no `xla` crate).
//!
//! [`Runtime::new`] still loads the artifact manifest, so model/dataset
//! loading and every native code path work unchanged; only the compiled
//! executors ([`StepExec::run`], [`QLinearExec::run`]) error out, telling
//! the caller to rebuild with `--features pjrt`. All call sites either
//! skip gracefully when artifacts are absent or propagate the error.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

use super::manifest::Manifest;

const NO_PJRT: &str =
    "PJRT execution not compiled in (rebuild with `--features pjrt` and a vendored `xla` crate)";

pub struct Runtime {
    pub manifest: Manifest,
}

impl Runtime {
    pub fn new(artifacts_dir: &str) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Runtime { manifest })
    }

    /// AdaRound step executable for a layer geometry.
    pub fn step_exec(&self, rows: usize, cols: usize, relu: bool) -> Result<StepExec> {
        let _ = (rows, cols, relu);
        bail!("{NO_PJRT}");
    }

    /// Quantized-matmul inference executable for a layer geometry.
    pub fn qlinear_exec(&self, rows: usize, cols: usize, batch: usize) -> Result<QLinearExec> {
        let _ = (rows, cols, batch);
        bail!("{NO_PJRT}");
    }

    pub fn compiled_count(&self) -> usize {
        0
    }
}

/// Mutable optimizer state shuttled through the step executable.
pub struct StepState {
    pub v: Tensor,
    pub m: Tensor,
    pub v2: Tensor,
    pub t: usize,
}

impl StepState {
    pub fn new(v: Tensor) -> StepState {
        let m = Tensor::zeros(&v.shape);
        let v2 = Tensor::zeros(&v.shape);
        StepState { v, m, v2, t: 0 }
    }
}

/// Stub of the compiled AdaRound step artifact (never constructed).
pub struct StepExec {
    pub rows: usize,
    pub cols: usize,
    pub batch: usize,
}

impl StepExec {
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &self,
        _state: &mut StepState,
        _x: &Tensor,
        _t_target: &Tensor,
        _w: &Tensor,
        _s: &Tensor,
        _b: &Tensor,
        _beta: f32,
        _lam: f32,
        _lr: f32,
        _n: f32,
        _p: f32,
    ) -> Result<(f64, f64)> {
        bail!("{NO_PJRT}");
    }
}

/// Stub of the compiled quantized-matmul artifact (never constructed).
pub struct QLinearExec {
    pub rows: usize,
    pub cols: usize,
    pub batch: usize,
}

impl QLinearExec {
    pub fn run(
        &self,
        _w: &Tensor,
        _r: &Tensor,
        _s: &Tensor,
        _b: &Tensor,
        _x: &Tensor,
        _n: f32,
        _p: f32,
    ) -> Result<Tensor> {
        bail!("{NO_PJRT}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executors_error_without_pjrt() {
        let exec = StepExec { rows: 2, cols: 2, batch: 4 };
        let mut state = StepState::new(Tensor::zeros(&[2, 2]));
        let x = Tensor::zeros(&[2, 4]);
        let t = Tensor::zeros(&[2, 4]);
        let w = Tensor::zeros(&[2, 2]);
        let s = Tensor::full(&[2, 1], 0.1);
        let b = Tensor::zeros(&[2, 1]);
        let err = exec
            .run(&mut state, &x, &t, &w, &s, &b, 8.0, 0.01, 0.01, -8.0, 7.0)
            .unwrap_err();
        assert!(format!("{err}").contains("pjrt"));
    }

    #[test]
    fn runtime_new_requires_manifest() {
        assert!(Runtime::new("/definitely/missing/dir").is_err());
    }
}
