//! CPU PJRT client + compiled-executable cache.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};

use super::exec::{QLinearExec, StepExec};
use super::manifest::Manifest;

pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<BTreeMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn new(artifacts_dir: &str) -> Result<Runtime> {
        // silence TfrtCpuClient created/destroyed chatter
        if std::env::var("TF_CPP_MIN_LOG_LEVEL").is_err() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, manifest, cache: RefCell::new(BTreeMap::new()) })
    }

    /// Load + compile an HLO-text artifact (cached by relative path).
    pub fn compile(&self, rel_path: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(rel_path) {
            return Ok(e.clone());
        }
        let full = self.manifest.dir.join(rel_path);
        let proto = xla::HloModuleProto::from_text_file(
            full.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse HLO {full:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {rel_path}: {e:?}"))?;
        let rc = Rc::new(exe);
        self.cache.borrow_mut().insert(rel_path.to_string(), rc.clone());
        Ok(rc)
    }

    /// AdaRound step executable for a layer geometry.
    pub fn step_exec(&self, rows: usize, cols: usize, relu: bool) -> Result<StepExec> {
        let spec = self
            .manifest
            .find_exec("adaround_step", rows, cols, relu)
            .with_context(|| format!("no adaround_step artifact for r{rows} c{cols} relu={relu}"))?
            .clone();
        let exe = self.compile(&spec.file)?;
        Ok(StepExec { exe, rows, cols, batch: spec.batch })
    }

    /// Quantized-matmul inference executable for a layer geometry.
    pub fn qlinear_exec(&self, rows: usize, cols: usize, batch: usize) -> Result<QLinearExec> {
        let spec = self
            .manifest
            .find_qlinear(rows, cols, batch)
            .with_context(|| format!("no qlinear artifact for r{rows} c{cols} n{batch}"))?
            .clone();
        let exe = self.compile(&spec.file)?;
        Ok(QLinearExec { exe, rows, cols, batch })
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}
