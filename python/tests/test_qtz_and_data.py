"""Interchange format round-trip + dataset generator properties."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import datagen, qtz


class TestQtz:
    def test_roundtrip(self, tmp_path):
        rng = np.random.default_rng(0)
        tensors = {
            "w": rng.normal(0, 1, (4, 3, 3, 3)).astype(np.float32),
            "labels": rng.integers(0, 10, (16,)).astype(np.int32),
            "mask": rng.integers(0, 2, (8, 8)).astype(np.uint8),
            "scalarish": np.float32([3.5]),
        }
        path = str(tmp_path / "t.qtz")
        qtz.write_qtz(path, tensors)
        back = qtz.read_qtz(path)
        assert set(back) == set(tensors)
        for k in tensors:
            assert back[k].dtype == tensors[k].dtype
            np.testing.assert_array_equal(back[k], tensors[k])

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 5))
    def test_roundtrip_hypothesis(self, tmp_path_factory, seed, n):
        rng = np.random.default_rng(seed)
        tensors = {}
        for i in range(n):
            ndim = int(rng.integers(1, 4))
            shape = tuple(int(d) for d in rng.integers(1, 6, ndim))
            tensors[f"t{i}"] = rng.normal(0, 1, shape).astype(np.float32)
        path = str(tmp_path_factory.mktemp("qtz") / "t.qtz")
        qtz.write_qtz(path, tensors)
        back = qtz.read_qtz(path)
        for k in tensors:
            np.testing.assert_array_equal(back[k], tensors[k])

    def test_bad_magic_rejected(self, tmp_path):
        p = tmp_path / "bad.qtz"
        p.write_bytes(b"NOPE" + b"\x00" * 16)
        with pytest.raises(ValueError):
            qtz.read_qtz(str(p))


class TestDatagen:
    def test_deterministic(self):
        x1, y1 = datagen.gen_gabor(8, seed=42)
        x2, y2 = datagen.gen_gabor(8, seed=42)
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)

    def test_shapes_and_dtypes(self):
        for name, gen in datagen.GENERATORS.items():
            x, y = gen(6, seed=0)
            assert x.shape == (6, 3, 32, 32) and x.dtype == np.float32
            if name == "shapes":
                assert y.shape == (6, 32, 32) and y.dtype == np.int32
                assert y.max() < datagen.SEG_CLASSES
            else:
                assert y.shape == (6,) and y.dtype == np.int32
                assert y.max() < datagen.NUM_CLASSES

    def test_label_coverage(self):
        _, y = datagen.gen_gabor(400, seed=1)
        assert len(np.unique(y)) == datagen.NUM_CLASSES

    def test_classes_distinguishable(self):
        # mean intra-class pattern correlation should beat inter-class
        x, y = datagen.gen_gabor(200, seed=2, noise=0.1)
        flat = x.reshape(len(x), -1)
        flat = flat / np.linalg.norm(flat, axis=1, keepdims=True)
        sims = flat @ flat.T
        same = (y[:, None] == y[None, :]) & ~np.eye(len(y), dtype=bool)
        diff = y[:, None] != y[None, :]
        assert np.abs(sims[same]).mean() > np.abs(sims[diff]).mean() + 0.1

    def test_seg_has_foreground(self):
        _, m = datagen.gen_shapes(20, seed=3)
        assert (m > 0).mean() > 0.02
