"""Properties of the AdaRound relaxation primitives (eqs. 22-24)."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import relax


class TestRectSigmoid:
    @settings(max_examples=50, deadline=None)
    @given(v=st.floats(-50, 50))
    def test_range(self, v):
        h = float(relax.rect_sigmoid(jnp.float32(v)))
        assert 0.0 <= h <= 1.0

    def test_saturation(self):
        assert float(relax.rect_sigmoid(jnp.float32(10.0))) == 1.0
        assert float(relax.rect_sigmoid(jnp.float32(-10.0))) == 0.0

    def test_monotone(self):
        vs = jnp.linspace(-6, 6, 201)
        hs = np.asarray(relax.rect_sigmoid(vs))
        assert np.all(np.diff(hs) >= -1e-7)

    def test_grad_matches_autodiff(self):
        vs = jnp.linspace(-5, 5, 101)
        g_manual = np.asarray(relax.rect_sigmoid_grad(vs))
        g_auto = np.asarray(jax.vmap(jax.grad(relax.rect_sigmoid))(vs))
        np.testing.assert_allclose(g_manual, g_auto, atol=1e-6)

    def test_nonvanishing_gradient_near_extremes(self):
        # the paper's motivation for the *rectified* sigmoid: h' > 0 while
        # h is strictly inside (0,1), even close to the boundary
        v = jnp.float32(np.log((0.999 / (relax.ZETA - relax.GAMMA) - relax.GAMMA /
                                (relax.ZETA - relax.GAMMA)) /
                               (1 - (0.999 - relax.GAMMA) / (relax.ZETA - relax.GAMMA))))
        h = float(relax.rect_sigmoid(v))
        assert 0.0 < h < 1.0
        assert float(relax.rect_sigmoid_grad(v)) > 1e-3


class TestFReg:
    def test_zero_at_binary(self):
        v = jnp.asarray([-20.0, 20.0, -15.0, 15.0])
        assert float(relax.f_reg(v, 4.0)) < 1e-6

    def test_max_at_half(self):
        # h = 0.5 at v = logit((0.5-gamma)/(zeta-gamma))
        q = (0.5 - relax.GAMMA) / (relax.ZETA - relax.GAMMA)
        v = jnp.float32(np.log(q / (1 - q)))
        assert abs(float(relax.f_reg(v, 2.0)) - 1.0) < 1e-5

    @settings(max_examples=30, deadline=None)
    @given(v=st.floats(-8, 8), beta=st.floats(2, 20))
    def test_bounds(self, v, beta):
        r = float(relax.f_reg(jnp.float32(v), jnp.float32(beta)))
        assert -1e-6 <= r <= 1.0 + 1e-6

    def test_annealing_effect(self):
        # higher beta -> smaller penalty for h away from 0.5 (Fig. 2 shape)
        v = jnp.float32(1.5)  # h somewhere between 0.5 and 1
        h = float(relax.rect_sigmoid(v))
        assert 0.5 < h < 1.0
        r_hi = float(relax.f_reg(v, 16.0))
        r_lo = float(relax.f_reg(v, 2.0))
        assert r_hi > r_lo


class TestInitV:
    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([0.01, 0.05, 0.3]))
    def test_inverse_property(self, seed, scale):
        # h(init_v(W, s)) == frac(W/s): soft quantization starts at FP32
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(0, 0.3, (8, 8)), jnp.float32)
        s = jnp.full((8, 1), scale, jnp.float32)
        v = relax.init_v_from_weights(w, s)
        h = relax.rect_sigmoid(v)
        frac = w / s - jnp.floor(w / s)
        np.testing.assert_allclose(h, jnp.clip(frac, 1e-4, 1 - 1e-4),
                                   rtol=2e-3, atol=2e-3)
