"""Contract tests over the built artifacts (skipped until `make artifacts`):
the manifest the rust runtime consumes must be complete and well-formed."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built"
)


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_models_and_files_present(manifest):
    assert set(manifest["models"]) == {
        "micro18", "micro50", "microinc", "micromobile", "segnet"}
    for name, entry in manifest["models"].items():
        assert os.path.exists(os.path.join(ART, entry["weights"])), name
        assert entry["task"] in ("cls", "seg")
        assert entry["ir"][0]["op"] == "input"


def test_datasets_present(manifest):
    for name, entry in manifest["datasets"].items():
        assert os.path.exists(os.path.join(ART, entry["file"])), name
        assert entry["n"] > 0


def test_step_buckets_cover_all_layer_geometries(manifest):
    from compile.aot import quantizable_layers
    buckets = {
        (e["rows"], e["cols"], e["relu"])
        for e in manifest["executables"]
        if e["kind"] == "adaround_step"
    }
    for name, entry in manifest["models"].items():
        for nd, rows, cols, relu in quantizable_layers(entry["ir"]):
            assert (rows, cols, relu) in buckets, (name, nd["id"], rows, cols, relu)


def test_hlo_files_exist_and_parse_shape(manifest):
    for e in manifest["executables"]:
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), e["file"]
        head = open(path).read(4096)
        assert "ENTRY" in open(path).read(), e["file"]
        del head


def test_weights_roundtrip_and_match_ir(manifest):
    from compile import qtz
    entry = manifest["models"]["micro18"]
    weights = qtz.read_qtz(os.path.join(ART, entry["weights"]))
    for nd in entry["ir"]:
        if nd["op"] == "conv":
            w = weights[nd["id"] + ".w"]
            assert w.shape == (nd["cout"], nd["cin"] // nd["groups"],
                               nd["k"], nd["k"])
            assert weights[nd["id"] + ".b"].shape == (nd["cout"],)
        elif nd["op"] == "dense":
            assert weights[nd["id"] + ".w"].shape == (nd["cout"], nd["cin"])
