"""Model zoo: graph construction, shapes, BN-fold exactness."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import models
from compile.aot import quantizable_layers, spatial_after


@pytest.mark.parametrize("name", list(models.BUILDERS))
def test_graph_builds_and_runs(name):
    nodes = models.BUILDERS[name]()
    params = {k: jnp.asarray(v) for k, v in models.init_params(nodes, 0).items()}
    state = {k: jnp.asarray(v) for k, v in models.init_bn_state(nodes).items()}
    x = jnp.zeros((2, 3, 32, 32), jnp.float32)
    out, _ = models.apply_graph(nodes, params, state, x, train=False)
    if models.TASKS[name] == "cls":
        assert out.shape == (2, 10)
    else:
        assert out.shape == (2, 4, 32, 32)


@pytest.mark.parametrize("name", list(models.BUILDERS))
def test_bn_fold_exact(name):
    """Folded conv(+bias) must equal conv+BN(running stats) in eval mode."""
    rng = np.random.default_rng(3)
    nodes = models.BUILDERS[name]()
    params = models.init_params(nodes, 1)
    state = models.init_bn_state(nodes)
    # randomize BN state so folding is non-trivial
    for k in state:
        if k.endswith(".mean"):
            state[k] = rng.normal(0, 0.5, state[k].shape).astype(np.float32)
        else:
            state[k] = (np.abs(rng.normal(1, 0.3, state[k].shape)) + 0.1).astype(np.float32)
    for k in params:
        if ".bn." in k:
            params[k] = rng.normal(1.0 if k.endswith(".g") else 0.0, 0.2,
                                   params[k].shape).astype(np.float32)
    jp = {k: jnp.asarray(v) for k, v in params.items()}
    js = {k: jnp.asarray(v) for k, v in state.items()}
    x = jnp.asarray(rng.normal(0, 1, (2, 3, 32, 32)), jnp.float32)
    y_ref, _ = models.apply_graph(nodes, jp, js, x, train=False)

    folded_ir, weights = models.fold_bn(nodes, params, state)
    jw = {k: jnp.asarray(v) for k, v in weights.items()}
    y_fold, _ = models.apply_graph(folded_ir, jw, {}, x, train=False)
    np.testing.assert_allclose(y_ref, y_fold, rtol=1e-4, atol=1e-4)


def test_quantizable_layers_micro18():
    nodes = models.build_micro18()
    qs = quantizable_layers(nodes)
    # stem + 6 blocks x 2 convs + 2 downsample skips + 1 dense
    assert len(qs) == 16
    nd, rows, cols, relu = qs[0]
    assert (rows, cols) == (8, 27) and relu  # stem: 3*3*3=27
    assert qs[-1][0]["op"] == "dense"


def test_depthwise_cols():
    nodes = models.build_micromobile()
    dws = [(nd, r, c) for nd, r, c, _ in quantizable_layers(nodes)
           if nd["op"] == "conv" and nd["groups"] > 1]
    assert dws, "micromobile must contain depthwise convs"
    for nd, rows, cols in dws:
        assert cols == 9  # 1 input channel per group * 3*3


def test_spatial_after():
    nodes = models.build_micro18()
    qs = quantizable_layers(nodes)
    assert spatial_after(nodes, qs[0][0]["id"]) == 32      # stem keeps 32
    assert spatial_after(nodes, qs[-2][0]["id"]) in (8, 16)  # deep layer


def test_param_counts_reasonable():
    for name, build in models.BUILDERS.items():
        nodes = build()
        params = models.init_params(nodes, 0)
        n = sum(int(np.prod(v.shape)) for v in params.values())
        assert 1_000 < n < 200_000, (name, n)
