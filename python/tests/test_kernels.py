"""Pallas kernel vs pure-jnp oracle — the CORE build-time correctness signal.

The hypothesis sweeps exercise non-block-aligned shapes, degenerate sizes
and extreme scales; the custom-vjp is checked against the oracle's autodiff.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import qlinear, ref, relax, softquant

F32 = jnp.float32


def _problem(rng, rows, cols, batch, scale=0.05):
    w = jnp.asarray(rng.normal(0, 0.3, (rows, cols)), F32)
    v = jnp.asarray(rng.normal(0, 2.0, (rows, cols)), F32)
    s = jnp.asarray(np.abs(rng.normal(scale, scale / 4, (rows, 1))) + 1e-4, F32)
    x = jnp.asarray(rng.normal(0, 1, (cols, batch)), F32)
    return w, v, s, x


NP4 = (jnp.float32(-8.0), jnp.float32(7.0))


class TestSoftQuantForward:
    def test_matches_ref_basic(self):
        rng = np.random.default_rng(0)
        w, v, s, x = _problem(rng, 32, 64, 192)
        n, p = NP4
        y = softquant.softquant_matmul(w, v, s, x, n, p)
        yr = ref.softquant_matmul_ref(w, v, s, x, n, p)
        np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)

    def test_non_block_aligned(self):
        rng = np.random.default_rng(1)
        w, v, s, x = _problem(rng, 33, 71, 97)
        n, p = NP4
        y = softquant.softquant_matmul(w, v, s, x, n, p)
        yr = ref.softquant_matmul_ref(w, v, s, x, n, p)
        np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)

    def test_gate_matches_ref(self):
        rng = np.random.default_rng(2)
        w, v, s, x = _problem(rng, 24, 48, 32)
        n, p = NP4
        _, g = softquant.softquant_matmul_with_gate(w, v, s, x, n, p)
        gr = ref.softquant_gate_ref(w, v, s, n, p)
        np.testing.assert_allclose(g, gr, rtol=1e-5, atol=1e-6)

    def test_clip_saturation_zeroes_gate(self):
        # weights far outside the grid: clip active => gate must be 0
        rng = np.random.default_rng(3)
        w = jnp.full((8, 8), 10.0, F32)  # floor(10/0.05)=200 >> p=7
        v = jnp.asarray(rng.normal(0, 1, (8, 8)), F32)
        s = jnp.full((8, 1), 0.05, F32)
        x = jnp.asarray(rng.normal(0, 1, (8, 16)), F32)
        n, p = NP4
        _, g = softquant.softquant_matmul_with_gate(w, v, s, x, n, p)
        np.testing.assert_allclose(g, np.zeros((8, 8)), atol=0)

    @settings(max_examples=25, deadline=None)
    @given(
        rows=st.integers(1, 70),
        cols=st.integers(1, 90),
        batch=st.integers(1, 130),
        seed=st.integers(0, 2**31 - 1),
        scale=st.sampled_from([1e-3, 0.05, 0.5]),
    )
    def test_hypothesis_shapes(self, rows, cols, batch, seed, scale):
        rng = np.random.default_rng(seed)
        w, v, s, x = _problem(rng, rows, cols, batch, scale)
        n, p = NP4
        y = softquant.softquant_matmul(w, v, s, x, n, p)
        yr = ref.softquant_matmul_ref(w, v, s, x, n, p)
        np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-4)


class TestSoftQuantVjp:
    def test_grad_matches_oracle(self):
        rng = np.random.default_rng(4)
        w, v, s, x = _problem(rng, 16, 36, 48)
        n, p = NP4
        t = ref.softquant_matmul_ref(w, v, s, x, n, p) + 0.05

        def f(vv):
            return jnp.mean((softquant.softquant_matmul(w, vv, s, x, n, p) - t) ** 2)

        def fr(vv):
            return jnp.mean((ref.softquant_matmul_ref(w, vv, s, x, n, p) - t) ** 2)

        dv, dvr = jax.grad(f)(v), jax.grad(fr)(v)
        np.testing.assert_allclose(dv, dvr, rtol=1e-4, atol=1e-7)

    @settings(max_examples=15, deadline=None)
    @given(rows=st.integers(2, 40), cols=st.integers(2, 60),
           batch=st.integers(2, 64), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_grad(self, rows, cols, batch, seed):
        rng = np.random.default_rng(seed)
        w, v, s, x = _problem(rng, rows, cols, batch)
        n, p = NP4
        t = jnp.asarray(rng.normal(0, 1, (rows, batch)), F32)
        f = lambda vv: jnp.mean((softquant.softquant_matmul(w, vv, s, x, n, p) - t) ** 2)
        fr = lambda vv: jnp.mean((ref.softquant_matmul_ref(w, vv, s, x, n, p) - t) ** 2)
        np.testing.assert_allclose(jax.grad(f)(v), jax.grad(fr)(v),
                                   rtol=2e-4, atol=1e-6)

    def test_finite_difference(self):
        # independent of both implementations: FD check of the custom vjp
        rng = np.random.default_rng(5)
        w, v, s, x = _problem(rng, 6, 8, 12)
        n, p = NP4
        t = jnp.zeros((6, 12), F32)
        f = lambda vv: jnp.mean((softquant.softquant_matmul(w, vv, s, x, n, p) - t) ** 2)
        g = np.asarray(jax.grad(f)(v))
        eps = 1e-3
        for (i, j) in [(0, 0), (3, 5), (5, 7)]:
            e = np.zeros_like(v)
            e[i, j] = eps
            fd = (float(f(v + e)) - float(f(v - e))) / (2 * eps)
            assert abs(fd - g[i, j]) < 5e-3 * max(1.0, abs(fd)), (i, j, fd, g[i, j])


class TestQLinear:
    def test_matches_ref(self):
        rng = np.random.default_rng(6)
        w, _, s, x = _problem(rng, 40, 54, 100)
        r = jnp.asarray(rng.integers(0, 2, (40, 54)), F32)
        n, p = NP4
        y = qlinear.qlinear_matmul(w, r, s, x, n, p)
        yr = ref.qlinear_ref(w, r, s, x, n, p)
        np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)

    def test_nearest_mask_is_round_to_nearest(self):
        rng = np.random.default_rng(7)
        w, _, s, x = _problem(rng, 16, 24, 32)
        n, p = NP4
        r = (w / s - jnp.floor(w / s) >= 0.5).astype(F32)
        y = qlinear.qlinear_matmul(w, r, s, x, n, p)
        wq = s * jnp.clip(jnp.round(w / s), n, p)
        np.testing.assert_allclose(y, wq @ x, rtol=1e-5, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(rows=st.integers(1, 64), cols=st.integers(1, 80),
           batch=st.integers(1, 140), seed=st.integers(0, 2**31 - 1),
           bits=st.sampled_from([2, 4, 8]))
    def test_hypothesis_bitwidths(self, rows, cols, batch, seed, bits):
        rng = np.random.default_rng(seed)
        w, _, s, x = _problem(rng, rows, cols, batch)
        r = jnp.asarray(rng.integers(0, 2, (rows, cols)), F32)
        n = jnp.float32(-(2 ** (bits - 1)))
        p = jnp.float32(2 ** (bits - 1) - 1)
        y = qlinear.qlinear_matmul(w, r, s, x, n, p)
        yr = ref.qlinear_ref(w, r, s, x, n, p)
        np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-4)
