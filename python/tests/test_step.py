"""The L2 AdaRound step graph: pallas path vs jnp-oracle path, Adam math,
convergence behaviour, and HLO lowering."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model
from compile.aot import to_hlo_text
from compile.kernels import relax

F32 = jnp.float32


def _layer_problem(seed, r=16, c=27, b=64):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 0.3, (r, c)), F32)
    s = jnp.full((r, 1), 0.05, F32)
    bias = jnp.asarray(rng.normal(0, 0.1, (r, 1)), F32)
    x = jnp.asarray(rng.normal(0, 1, (c, b)), F32)
    t = w @ x + bias
    v = relax.init_v_from_weights(w, s)
    return w, s, bias, x, t, v


def _consts():
    return (jnp.float32(0.01), jnp.float32(0.01),
            jnp.float32(-8.0), jnp.float32(7.0))


class TestStepEquivalence:
    def test_pallas_equals_jnp_path(self):
        for relu in (False, True):
            w, s, bias, x, t, v = _layer_problem(0)
            lam, lr, n, p = _consts()
            sp = model.make_adaround_step(relu=relu, use_pallas=True)
            sj = model.make_adaround_step(relu=relu, use_pallas=False)
            m = jnp.zeros_like(v); v2 = jnp.zeros_like(v)
            args = (v, m, v2, jnp.float32(1.0), x, t, w, s, bias,
                    jnp.float32(8.0), lam, lr, n, p)
            out_p, out_j = sp(*args), sj(*args)
            for a, b in zip(out_p, out_j):
                np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)

    def test_adam_bias_correction(self):
        # one step from zero moments: update = -lr * g/(|g|+eps) elementwise
        w, s, bias, x, t, v = _layer_problem(1)
        lam, lr, n, p = _consts()
        step = model.make_adaround_step(relu=False)
        m = jnp.zeros_like(v); v2 = jnp.zeros_like(v)
        v1, m1, v21, loss, mse = step(v, m, v2, jnp.float32(1.0), x, t, w, s,
                                      bias, jnp.float32(8.0), lam, lr, n, p)
        g = m1 / (1.0 - model.ADAM_B1)  # recover grad from first moment
        expect = v - lr * g / (jnp.sqrt(g * g) + model.ADAM_EPS)
        np.testing.assert_allclose(v1, expect, rtol=1e-4, atol=1e-6)


class TestConvergence:
    def test_loss_decreases_and_h_binarizes(self):
        w, s, bias, x, t, v = _layer_problem(2, r=12, c=20, b=96)
        lam, lr, n, p = jnp.float32(0.02), jnp.float32(0.02), jnp.float32(-8), jnp.float32(7)
        step = jax.jit(model.make_adaround_step(relu=True))
        m = jnp.zeros_like(v); v2 = jnp.zeros_like(v)
        first_mse = None
        iters = 400
        for i in range(1, iters + 1):
            frac = i / iters
            beta = jnp.float32(20.0 - (20.0 - 2.0) * frac)
            v, m, v2, loss, mse = step(v, m, v2, jnp.float32(i), x, t, w, s,
                                       bias, beta, lam, lr, n, p)
            if first_mse is None:
                first_mse = float(mse)
        assert float(mse) <= first_mse * 1.05
        h = np.asarray(relax.rect_sigmoid(v))
        frac_binary = np.mean((h < 0.05) | (h > 0.95))
        assert frac_binary > 0.8, f"h failed to binarize: {frac_binary}"

    def test_adaround_beats_nearest_on_mse(self):
        # after optimization, rounding by h>=0.5 should reconstruct WX at
        # least as well as round-to-nearest (the paper's core claim, layer-wise)
        w, s, bias, x, t, v = _layer_problem(3, r=12, c=20, b=96)
        n, p = jnp.float32(-8), jnp.float32(7)
        lam, lr = jnp.float32(0.01), jnp.float32(0.02)
        step = jax.jit(model.make_adaround_step(relu=False))
        m = jnp.zeros_like(v); v2 = jnp.zeros_like(v)
        for i in range(1, 501):
            beta = jnp.float32(max(2.0, 20.0 - 18.0 * i / 500))
            v, m, v2, loss, mse = step(v, m, v2, jnp.float32(i), x, t, w, s,
                                       bias, beta, lam, lr, n, p)
        rounding = (np.asarray(relax.rect_sigmoid(v)) >= 0.5).astype(np.float32)
        wq_ada = s * jnp.clip(jnp.floor(w / s) + rounding, n, p)
        wq_near = s * jnp.clip(jnp.round(w / s), n, p)
        mse_ada = float(jnp.mean((wq_ada @ x + bias - t) ** 2))
        mse_near = float(jnp.mean((wq_near @ x + bias - t) ** 2))
        assert mse_ada <= mse_near * 1.001, (mse_ada, mse_near)


class TestLowering:
    def test_step_lowers_to_hlo_text(self):
        low = jax.jit(model.make_adaround_step(relu=True)).lower(
            *model.step_example_args(8, 12, 32))
        txt = to_hlo_text(low)
        assert "ENTRY" in txt and "f32[8,12]" in txt

    def test_qlinear_lowers_to_hlo_text(self):
        low = jax.jit(model.make_qlinear_fwd()).lower(
            *model.qlinear_example_args(8, 12, 64))
        txt = to_hlo_text(low)
        assert "ENTRY" in txt and "f32[8,64]" in txt
