"""`.qtz` tensor-bundle interchange format (python writer/reader).

A minimal, dependency-free binary container shared between the build-time
python side and the rust runtime (rust/src/io/qtz.rs mirrors this exactly).

Layout (all integers little-endian):

    magic   : 4 bytes  b"QTZ1"
    count   : u32      number of tensors
    per tensor:
        name_len : u16
        name     : utf-8 bytes
        dtype    : u8   (0 = f32, 1 = i32, 2 = u8, 3 = i8)
        ndim     : u8
        dims     : u32 * ndim
        data     : raw little-endian values (prod(dims) elements)
"""

from __future__ import annotations

import struct
from typing import Dict

import numpy as np

MAGIC = b"QTZ1"

_DTYPE_TO_CODE = {
    np.dtype(np.float32): 0,
    np.dtype(np.int32): 1,
    np.dtype(np.uint8): 2,
    np.dtype(np.int8): 3,
}
_CODE_TO_DTYPE = {0: np.float32, 1: np.int32, 2: np.uint8, 3: np.int8}


def write_qtz(path: str, tensors: Dict[str, np.ndarray]) -> None:
    """Write a named tensor bundle. Tensors are cast to a supported dtype."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.asarray(arr)
            if arr.dtype not in _DTYPE_TO_CODE:
                if np.issubdtype(arr.dtype, np.floating):
                    arr = arr.astype(np.float32)
                elif np.issubdtype(arr.dtype, np.integer):
                    arr = arr.astype(np.int32)
                else:
                    raise ValueError(f"unsupported dtype {arr.dtype} for {name}")
            code = _DTYPE_TO_CODE[arr.dtype]
            name_b = name.encode("utf-8")
            f.write(struct.pack("<H", len(name_b)))
            f.write(name_b)
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype(arr.dtype, order="C").tobytes())


def read_qtz(path: str) -> Dict[str, np.ndarray]:
    """Read a bundle back (used by tests to round-trip)."""
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        magic = f.read(4)
        if magic != MAGIC:
            raise ValueError(f"bad magic {magic!r} in {path}")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (name_len,) = struct.unpack("<H", f.read(2))
            name = f.read(name_len).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dtype = np.dtype(_CODE_TO_DTYPE[code])
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(n * dtype.itemsize), dtype=dtype)
            out[name] = data.reshape(dims).copy()
    return out
