"""AOT pipeline: train the zoo, export weights/data, lower HLO artifacts.

Runs exactly once via ``make artifacts``.  Products (all under artifacts/):

    manifest.json                 model IRs + file index + executable table
    <model>.weights.qtz           BN-folded FP32 weights
    data/<name>.qtz               calibration / validation tensor bundles
    hlo/step_r{R}_c{C}_b{B}_{act}.hlo.txt      AdaRound step executables
    hlo/qlinear_r{R}_c{C}_n{N}.hlo.txt         inference executables

HLO **text** is the interchange format (NOT ``.serialize()``): jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen, model, qtz, trainer
from .models import BUILDERS, TASKS

STEP_BATCH = 192       # im2col columns per AdaRound step
QLINEAR_IMGS = 32      # images per qlinear inference execution
CALIB_N = 2048
VAL_N = 1024

# Models for which per-layer qlinear inference artifacts are emitted (the
# PJRT engine demo / bench; the native engine covers all models).
QLINEAR_MODELS = ("micro18",)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def quantizable_layers(nodes):
    """(node, rows, cols, relu) for every weight-bearing node, in graph order.

    rows = out channels *per group*, cols = im2col patch size
    (cin/groups * k * k).  Grouped convolutions are optimized one group at a
    time (each group owns a distinct im2col matrix), so the shape bucket is
    the per-group GEMM geometry."""
    out = []
    for nd in nodes:
        if nd["op"] == "conv":
            cols = (nd["cin"] // nd["groups"]) * nd["k"] * nd["k"]
            out.append((nd, nd["cout"] // nd["groups"], cols, bool(nd["relu"])))
        elif nd["op"] == "dense":
            out.append((nd, nd["cout"], nd["cin"], bool(nd["relu"])))
    return out


def spatial_after(nodes, node_id, img=32):
    """Output spatial size (h*w) of a conv node, walking strides/pools on the
    path from the input. Dense nodes return 1."""
    # compute spatial size for every node
    size = {"in": img}
    for nd in nodes:
        if nd["op"] == "input":
            continue
        ins = nd["inputs"]
        base = size[ins[0]] if ins else img
        if nd["op"] == "conv":
            size[nd["id"]] = (base + nd["stride"] - 1) // nd["stride"]
        elif nd["op"] == "avgpool":
            size[nd["id"]] = base // nd["stride"]
        elif nd["op"] == "upsample":
            size[nd["id"]] = base * 2
        elif nd["op"] in ("gpool", "dense"):
            size[nd["id"]] = 1
        else:
            size[nd["id"]] = base
    return size.get(node_id, 1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/manifest.json")
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("QTZ_TRAIN_STEPS", "600")))
    ap.add_argument("--models", default=",".join(BUILDERS.keys()))
    args = ap.parse_args()

    art_dir = os.path.dirname(os.path.abspath(args.out))
    hlo_dir = os.path.join(art_dir, "hlo")
    data_dir = os.path.join(art_dir, "data")
    os.makedirs(hlo_dir, exist_ok=True)
    os.makedirs(data_dir, exist_ok=True)

    manifest = {"models": {}, "executables": [], "datasets": {},
                "step_batch": STEP_BATCH, "qlinear_imgs": QLINEAR_IMGS}

    # ---------------- datasets (calibration + validation) ----------------
    t0 = time.time()
    print("== generating datasets")
    sets = {
        "calib_gabor": datagen.gen_gabor(CALIB_N, seed=101),
        "val_gabor": datagen.gen_gabor(VAL_N, seed=202),
        "calib_checker": datagen.gen_checker(CALIB_N, seed=303),
        "calib_shapes": datagen.gen_shapes(512, seed=404),
        "val_shapes": datagen.gen_shapes(512, seed=505),
    }
    for name, (x, y) in sets.items():
        path = os.path.join(data_dir, f"{name}.qtz")
        qtz.write_qtz(path, {"x": x, "y": y})
        manifest["datasets"][name] = {"file": f"data/{name}.qtz", "n": len(x)}
    print(f"   datasets done in {time.time()-t0:.0f}s")

    # ---------------- train + export the zoo ----------------
    step_buckets = set()     # (rows, cols, relu)
    qlinear_buckets = set()  # (rows, cols, ncols)
    for mname in args.models.split(","):
        print(f"== training {mname}")
        steps = args.steps if TASKS[mname] == "cls" else max(args.steps, 800)
        ir, weights, report = trainer.train_model(mname, steps=steps)
        wfile = f"{mname}.weights.qtz"
        qtz.write_qtz(os.path.join(art_dir, wfile), weights)
        manifest["models"][mname] = {
            "ir": ir, "weights": wfile, "task": TASKS[mname],
            "fp32_report": report,
        }
        for nd, rows, cols, relu in quantizable_layers(ir):
            step_buckets.add((rows, cols, relu))
            if mname in QLINEAR_MODELS:
                hw = spatial_after(ir, nd["id"]) ** 2
                qlinear_buckets.add((rows, cols, QLINEAR_IMGS * hw))

    # ---------------- lower HLO artifacts ----------------
    print(f"== lowering {len(step_buckets)} step + {len(qlinear_buckets)} "
          f"qlinear artifacts")
    for rows, cols, relu in sorted(step_buckets):
        fn = model.make_adaround_step(relu=relu)
        lowered = jax.jit(fn).lower(*model.step_example_args(rows, cols, STEP_BATCH))
        act = "relu" if relu else "id"
        fname = f"hlo/step_r{rows}_c{cols}_b{STEP_BATCH}_{act}.hlo.txt"
        with open(os.path.join(art_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["executables"].append({
            "kind": "adaround_step", "rows": rows, "cols": cols,
            "batch": STEP_BATCH, "relu": relu, "file": fname,
        })
    for rows, cols, ncols in sorted(qlinear_buckets):
        fn = model.make_qlinear_fwd()
        lowered = jax.jit(fn).lower(*model.qlinear_example_args(rows, cols, ncols))
        fname = f"hlo/qlinear_r{rows}_c{cols}_n{ncols}.hlo.txt"
        with open(os.path.join(art_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["executables"].append({
            "kind": "qlinear", "rows": rows, "cols": cols,
            "batch": ncols, "relu": False, "file": fname,
        })

    with open(args.out, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"== artifacts complete in {time.time()-t0:.0f}s -> {args.out}")


if __name__ == "__main__":
    main()
