"""Shared AdaRound relaxation math (paper eqs. 22-24).

Used by the Pallas kernels, the L2 step graph, and the pure-jnp oracle so
all three agree on the exact definition of h(V) and f_reg.
"""

import jax
import jax.numpy as jnp

# Rectified-sigmoid stretch parameters (paper: zeta=1.1, gamma=-0.1).
ZETA = 1.1
GAMMA = -0.1


def rect_sigmoid(v):
    """h(V) = clip(sigmoid(V) * (zeta - gamma) + gamma, 0, 1)   (eq. 23)."""
    return jnp.clip(jax.nn.sigmoid(v) * (ZETA - GAMMA) + GAMMA, 0.0, 1.0)


def rect_sigmoid_grad(v):
    """dh/dV (zero where the rectification clips)."""
    s = jax.nn.sigmoid(v)
    raw = s * (ZETA - GAMMA) + GAMMA
    inside = ((raw > 0.0) & (raw < 1.0)).astype(v.dtype)
    return inside * s * (1.0 - s) * (ZETA - GAMMA)


def f_reg(v, beta):
    """sum_ij 1 - |2 h(V_ij) - 1|^beta   (eq. 24)."""
    h = rect_sigmoid(v)
    return jnp.sum(1.0 - jnp.abs(2.0 * h - 1.0) ** beta)


def init_v_from_weights(w, s):
    """Initialize V so that h(V) equals the fractional part of W/s
    (i.e. soft-quantization starts exactly at the FP32 weights).
    Inverse of the rectified sigmoid on the open interval (0,1)."""
    frac = w / s - jnp.floor(w / s)
    frac = jnp.clip(frac, 1e-4, 1.0 - 1e-4)
    p = (frac - GAMMA) / (ZETA - GAMMA)
    return jnp.log(p / (1.0 - p))
