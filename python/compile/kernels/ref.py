"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is straight-line jnp so ``jax.grad`` works and serves as the
autodiff oracle for the hand-derived custom-vjp of the Pallas pair.
"""

import jax.numpy as jnp

from . import relax


def soft_quant_weights(w, v, s, n, p):
    """W~ = s * clip(floor(W/s) + h(V), n, p)   (eq. 22)."""
    return s * jnp.clip(jnp.floor(w / s) + relax.rect_sigmoid(v), n, p)


def softquant_matmul_ref(w, v, s, x, n, p):
    """Y = W~ X  — the reconstruction forward (soft quantization)."""
    return soft_quant_weights(w, v, s, n, p) @ x


def softquant_gate_ref(w, v, s, n, p):
    """G = s * clip_mask * h'(V): the elementwise factor the backward kernel
    multiplies into (dY X^T) to produce dV."""
    z = jnp.floor(w / s) + relax.rect_sigmoid(v)
    mask = ((z >= n) & (z <= p)).astype(w.dtype)
    return s * mask * relax.rect_sigmoid_grad(v)


def hard_quant_weights(w, r, s, n, p):
    """W^ = s * clip(floor(W/s) + R, n, p) with a binary up/down mask R.
    R = (frac(W/s) >= 0.5) reproduces round-to-nearest."""
    return s * jnp.clip(jnp.floor(w / s) + r, n, p)


def qlinear_ref(w, r, s, x, n, p):
    """Y = W^ X — hard fake-quant matmul (inference path)."""
    return hard_quant_weights(w, r, s, n, p) @ x


def recon_loss_ref(v, w, s, x, t, beta, lam, n, p, relu):
    """Full relaxed objective (eq. 25): asymmetric reconstruction MSE of the
    (optionally ReLU-ed) pre-activations + lambda * f_reg."""
    y = softquant_matmul_ref(w, v, s, x, n, p)
    if relu:
        y = jnp.maximum(y, 0.0)
        t = jnp.maximum(t, 0.0)
    mse = jnp.mean((y - t) ** 2)
    return mse + lam * relax.f_reg(v, beta)
