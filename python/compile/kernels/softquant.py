"""Pallas kernels for the AdaRound hot-spot: soft-quantized matmul fwd/bwd.

Forward (per-layer reconstruction objective, paper eq. 21/25):

    W~ = s * clip(floor(W/s) + h(V), n, p)
    Y  = W~ @ X
    G  = s * clip_mask * h'(V)          (saved for the backward pass)

Backward (hand-derived VJP — interpret-mode ``pallas_call`` has no autodiff
rule, so the pair is registered as a ``jax.custom_vjp`` and cross-checked
against the jnp oracle's ``jax.grad`` in pytest/hypothesis):

    dV = (dY @ X^T) * G

TPU-shaped design (see DESIGN.md §Hardware-Adaptation): the grid tiles the
output (M/bm, N/bn); the W/V tile is loaded into VMEM once per row-block,
h(V), the integer floor and the clip are computed on-tile (W~ never hits
HBM), and the contraction uses ``jnp.dot(..., preferred_element_type=f32)``
so Mosaic maps it onto the MXU.  On this CPU image the kernels run under
``interpret=True`` (Mosaic custom-calls cannot execute on the CPU PJRT
plugin); block shapes below are chosen for the real-TPU VMEM budget and
documented in EXPERIMENTS.md §Perf.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import relax

# Block shapes. On TPU these would be (128, 128) MXU-aligned tiles; the
# sizes here keep the interpret-mode grid small while still exercising the
# multi-block code path in tests.
BM = 32  # output-row block (rows of W)
BN = 64  # output-col block (columns of X)
BK_FULL = True  # K (= cols of W) is kept resident per block-row


def _fwd_kernel(w_ref, v_ref, s_ref, x_ref, n_ref, p_ref, y_ref, g_ref):
    """One (bm, bn) output tile: soft-quantize the W tile, contract with X."""
    w = w_ref[...]
    v = v_ref[...]
    s = s_ref[...]
    n = n_ref[0]
    p = p_ref[0]
    sig = jax.nn.sigmoid(v)
    h = jnp.clip(sig * (relax.ZETA - relax.GAMMA) + relax.GAMMA, 0.0, 1.0)
    z = jnp.floor(w / s) + h
    wq = s * jnp.clip(z, n, p)
    y_ref[...] = jnp.dot(wq, x_ref[...], preferred_element_type=jnp.float32)
    # Gate for the backward pass: d(W~)/dV = s * 1[n<=z<=p] * h'(V).
    raw = sig * (relax.ZETA - relax.GAMMA) + relax.GAMMA
    hgrad = jnp.where((raw > 0.0) & (raw < 1.0),
                      sig * (1.0 - sig) * (relax.ZETA - relax.GAMMA), 0.0)
    mask = ((z >= n) & (z <= p)).astype(w.dtype)
    g_ref[...] = s * mask * hgrad


def _bwd_kernel(dy_ref, x_ref, g_ref, dv_ref):
    """dV tile = (dY @ X^T) tile * G tile."""
    dv_ref[...] = (
        jnp.dot(dy_ref[...], x_ref[...].T, preferred_element_type=jnp.float32)
        * g_ref[...]
    )


def _fwd_call(w, v, s, x, n, p):
    rows, cols = w.shape
    batch = x.shape[1]
    bm, bn = min(BM, rows), min(BN, batch)
    grid = (pl.cdiv(rows, bm), pl.cdiv(batch, bn))
    nv = jnp.reshape(n.astype(jnp.float32), (1,))
    pv = jnp.reshape(p.astype(jnp.float32), (1,))
    y, g = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, cols), lambda i, j: (i, 0)),   # W
            pl.BlockSpec((bm, cols), lambda i, j: (i, 0)),   # V
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),      # s (per-row)
            pl.BlockSpec((cols, bn), lambda i, j: (0, j)),   # X
            pl.BlockSpec((1,), lambda i, j: (0,)),           # n
            pl.BlockSpec((1,), lambda i, j: (0,)),           # p
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),     # Y
            pl.BlockSpec((bm, cols), lambda i, j: (i, 0)),   # G (idempotent over j)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, batch), jnp.float32),
            jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        ],
        interpret=True,
    )(w, v, s, x, nv, pv)
    return y, g


def _bwd_call(dy, x, g):
    rows, cols = g.shape
    batch = x.shape[1]
    bm, bk = min(BM, rows), min(BN, cols)
    grid = (pl.cdiv(rows, bm), pl.cdiv(cols, bk))
    dv = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, batch), lambda i, j: (i, 0)),  # dY
            pl.BlockSpec((bk, batch), lambda i, j: (j, 0)),  # X
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),     # G
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(dy, x, g)
    return dv


@jax.custom_vjp
def softquant_matmul(w, v, s, x, n, p):
    """Soft-quantized matmul Y = (s*clip(floor(W/s)+h(V), n, p)) @ X.

    Differentiable in V only (the AdaRound optimization variable); the VJP
    for every other argument is defined as zero, which is exact for the
    AdaRound use where W, s, X, n, p are constants of the layer problem.
    """
    y, _ = _fwd_call(w, v, s, x, n, p)
    return y


def _vjp_fwd(w, v, s, x, n, p):
    y, g = _fwd_call(w, v, s, x, n, p)
    return y, (g, x)


def _vjp_bwd(res, dy):
    g, x = res
    dv = _bwd_call(dy, x, g)
    zeros = lambda a: jnp.zeros_like(a)
    return (jnp.zeros_like(g), dv, jnp.zeros((g.shape[0], 1), jnp.float32),
            zeros(x), jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))


softquant_matmul.defvjp(_vjp_fwd, _vjp_bwd)


def softquant_matmul_with_gate(w, v, s, x, n, p):
    """Non-differentiable variant that also returns the gate (for tests)."""
    return _fwd_call(w, v, s, x, n, p)
