"""Pallas kernel for the inference hot path: hard fake-quant matmul.

    W^ = s * clip(floor(W/s) + R, n, p)     R in {0,1}: round down / up
    Y  = W^ @ X

R = (frac(W/s) >= 0.5) reproduces round-to-nearest; R = AdaRound's converged
h(V) mask is the quantized model the coordinator serves.  The quantized
weights are recomputed on-tile from (W, R, s) so the artifact is generic in
the rounding mask — the same executable serves nearest / stochastic /
AdaRound weights.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 32
BN = 128


def _qlinear_kernel(w_ref, r_ref, s_ref, x_ref, n_ref, p_ref, y_ref):
    w = w_ref[...]
    s = s_ref[...]
    wq = s * jnp.clip(jnp.floor(w / s) + r_ref[...], n_ref[0], p_ref[0])
    y_ref[...] = jnp.dot(wq, x_ref[...], preferred_element_type=jnp.float32)


def qlinear_matmul(w, r, s, x, n, p):
    """Y = W^ X with binary rounding mask R (same shapes as softquant)."""
    rows, cols = w.shape
    batch = x.shape[1]
    bm, bn = min(BM, rows), min(BN, batch)
    grid = (pl.cdiv(rows, bm), pl.cdiv(batch, bn))
    nv = jnp.reshape(n.astype(jnp.float32), (1,))
    pv = jnp.reshape(p.astype(jnp.float32), (1,))
    return pl.pallas_call(
        _qlinear_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, cols), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, cols), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((cols, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, batch), jnp.float32),
        interpret=True,
    )(w, r, s, x, nv, pv)
