"""Micro-network zoo: graph IR + pure-JAX executor + builders + BN folding.

The architecture of every model is expressed as a small graph IR (list of
node dicts).  The same IR is exported into ``artifacts/manifest.json`` and
interpreted by the rust inference engine (rust/src/nn) — the architecture is
defined exactly once, here.

Node schema (all fields JSON-serializable):
    {"id": str, "op": str, "inputs": [str], ...op-specific fields}

Ops:
    input                                   the image tensor [N,3,32,32]
    conv     {k, stride, pad, groups, relu, bn}   weight "<id>.w" [O,I/g,k,k]
    dense    {relu}                               weight "<id>.w" [O,I]
    add                                     elementwise sum of two inputs
    relu                                    standalone ReLU
    avgpool  {k, stride}                    average pooling
    gpool                                   global average pool -> [N,C]
    upsample                                nearest-neighbor x2
    concat                                  channel concat of inputs

``bn`` is a *training-time* flag: during training the conv is followed by a
BatchNorm whose parameters live beside the conv weight; at export the BN is
folded into the conv weight+bias (paper §5: "we absorb batch normalization
in the weights of the adjacent layers") and the flag is dropped from the IR.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

BN_EPS = 1e-5
BN_MOM = 0.9


# --------------------------------------------------------------------------
# Graph builder helpers
# --------------------------------------------------------------------------


class Graph:
    """Tiny helper to accumulate IR nodes with unique ids."""

    def __init__(self) -> None:
        self.nodes: List[dict] = [{"id": "in", "op": "input", "inputs": []}]
        self._n = 0

    def _fresh(self, prefix: str) -> str:
        self._n += 1
        return f"{prefix}{self._n}"

    def conv(self, x: str, cin: int, cout: int, k: int, stride: int = 1,
             groups: int = 1, relu: bool = True, bn: bool = True) -> str:
        nid = self._fresh("c")
        self.nodes.append({
            "id": nid, "op": "conv", "inputs": [x], "cin": cin, "cout": cout,
            "k": k, "stride": stride, "pad": k // 2, "groups": groups,
            "relu": relu, "bn": bn,
        })
        return nid

    def dense(self, x: str, cin: int, cout: int, relu: bool = False) -> str:
        nid = self._fresh("d")
        self.nodes.append({"id": nid, "op": "dense", "inputs": [x],
                           "cin": cin, "cout": cout, "relu": relu})
        return nid

    def add(self, a: str, b: str, relu: bool = True) -> str:
        nid = self._fresh("a")
        self.nodes.append({"id": nid, "op": "add", "inputs": [a, b], "relu": relu})
        return nid

    def avgpool(self, x: str, k: int = 2, stride: int = 2) -> str:
        nid = self._fresh("p")
        self.nodes.append({"id": nid, "op": "avgpool", "inputs": [x], "k": k, "stride": stride})
        return nid

    def gpool(self, x: str) -> str:
        nid = self._fresh("g")
        self.nodes.append({"id": nid, "op": "gpool", "inputs": [x]})
        return nid

    def upsample(self, x: str) -> str:
        nid = self._fresh("u")
        self.nodes.append({"id": nid, "op": "upsample", "inputs": [x]})
        return nid

    def concat(self, xs: List[str]) -> str:
        nid = self._fresh("k")
        self.nodes.append({"id": nid, "op": "concat", "inputs": list(xs)})
        return nid


# --------------------------------------------------------------------------
# Architectures
# --------------------------------------------------------------------------


def _basic_block(g: Graph, x: str, cin: int, cout: int, stride: int) -> str:
    c1 = g.conv(x, cin, cout, 3, stride=stride)
    c2 = g.conv(c1, cout, cout, 3, relu=False)
    if stride != 1 or cin != cout:
        skip = g.conv(x, cin, cout, 1, stride=stride, relu=False)
    else:
        skip = x
    return g.add(c2, skip)


def build_micro18() -> List[dict]:
    """Residual net with basic blocks — the Resnet18 analog.

    Channel widths are sized for the single-core CPU testbed (DESIGN.md §1);
    the 4-bit rounding phenomena are stronger, not weaker, at small width."""
    g = Graph()
    x = g.conv("in", 3, 8, 3)
    x = _basic_block(g, x, 8, 8, 1)
    x = _basic_block(g, x, 8, 8, 1)
    x = _basic_block(g, x, 8, 16, 2)
    x = _basic_block(g, x, 16, 16, 1)
    x = _basic_block(g, x, 16, 32, 2)
    x = _basic_block(g, x, 32, 32, 1)
    x = g.gpool(x)
    g.dense(x, 32, 10)
    return g.nodes


def _bottleneck(g: Graph, x: str, cin: int, cmid: int, cout: int, stride: int) -> str:
    c1 = g.conv(x, cin, cmid, 1)
    c2 = g.conv(c1, cmid, cmid, 3, stride=stride)
    c3 = g.conv(c2, cmid, cout, 1, relu=False)
    if stride != 1 or cin != cout:
        skip = g.conv(x, cin, cout, 1, stride=stride, relu=False)
    else:
        skip = x
    return g.add(c3, skip)


def build_micro50() -> List[dict]:
    """Deeper bottleneck-block net — the Resnet50 analog."""
    g = Graph()
    x = g.conv("in", 3, 8, 3)
    x = _bottleneck(g, x, 8, 4, 16, 1)
    x = _bottleneck(g, x, 16, 4, 16, 1)
    x = _bottleneck(g, x, 16, 8, 32, 2)
    x = _bottleneck(g, x, 32, 8, 32, 1)
    x = _bottleneck(g, x, 32, 16, 64, 2)
    x = _bottleneck(g, x, 64, 16, 64, 1)
    x = g.gpool(x)
    g.dense(x, 64, 10)
    return g.nodes


def _inception_cell(g: Graph, x: str, cin: int, b1: int, b2m: int, b2: int,
                    b3m: int, b3: int) -> Tuple[str, int]:
    p1 = g.conv(x, cin, b1, 1)
    p2 = g.conv(g.conv(x, cin, b2m, 1), b2m, b2, 3)
    p3 = g.conv(g.conv(x, cin, b3m, 1), b3m, b3, 3)
    return g.concat([p1, p2, p3]), b1 + b2 + b3


def build_microinc() -> List[dict]:
    """Parallel-branch cells — the InceptionV3 analog."""
    g = Graph()
    x = g.conv("in", 3, 8, 3)
    x, c = _inception_cell(g, x, 8, 4, 4, 4, 2, 4)
    x = g.avgpool(x)
    x, c = _inception_cell(g, x, c, 6, 6, 6, 3, 6)
    x = g.avgpool(x)
    x, c = _inception_cell(g, x, c, 8, 8, 8, 4, 8)
    x = g.gpool(x)
    g.dense(x, c, 10)
    return g.nodes


def _inverted_residual(g: Graph, x: str, cin: int, exp: int, cout: int, stride: int) -> str:
    mid = cin * exp
    c1 = g.conv(x, cin, mid, 1)
    c2 = g.conv(c1, mid, mid, 3, stride=stride, groups=mid)
    c3 = g.conv(c2, mid, cout, 1, relu=False)
    if stride == 1 and cin == cout:
        return g.add(c3, x, relu=False)
    return c3


def build_micromobile() -> List[dict]:
    """Depthwise-separable inverted residuals — the MobilenetV2 analog
    (depthwise layers make it notoriously hard to quantize per-tensor)."""
    g = Graph()
    x = g.conv("in", 3, 8, 3)
    x = _inverted_residual(g, x, 8, 2, 8, 1)
    x = _inverted_residual(g, x, 8, 2, 12, 2)
    x = _inverted_residual(g, x, 12, 2, 12, 1)
    x = _inverted_residual(g, x, 12, 2, 16, 2)
    x = _inverted_residual(g, x, 16, 2, 16, 1)
    x = g.conv(x, 16, 32, 1)
    x = g.gpool(x)
    g.dense(x, 32, 10)
    return g.nodes


def build_segnet() -> List[dict]:
    """Small U-shaped encoder-decoder — the DeeplabV3+ analog (per-pixel
    4-class output over 32x32)."""
    g = Graph()
    e1 = g.conv("in", 3, 8, 3)
    e2 = g.conv(e1, 8, 16, 3, stride=2)
    e3 = g.conv(e2, 16, 24, 3, stride=2)
    m = g.conv(e3, 24, 24, 3)
    u1 = g.upsample(m)
    d1 = g.conv(g.concat([u1, e2]), 24 + 16, 16, 3)
    u2 = g.upsample(d1)
    d2 = g.conv(g.concat([u2, e1]), 16 + 8, 8, 3)
    g.conv(d2, 8, 4, 1, relu=False, bn=False)
    return g.nodes


BUILDERS = {
    "micro18": build_micro18,
    "micro50": build_micro50,
    "microinc": build_microinc,
    "micromobile": build_micromobile,
    "segnet": build_segnet,
}

TASKS = {
    "micro18": "cls", "micro50": "cls", "microinc": "cls",
    "micromobile": "cls", "segnet": "seg",
}


# --------------------------------------------------------------------------
# Parameter init
# --------------------------------------------------------------------------


def init_params(nodes: List[dict], seed: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    params: Dict[str, np.ndarray] = {}
    for nd in nodes:
        if nd["op"] == "conv":
            cin_g = nd["cin"] // nd["groups"]
            fan_in = cin_g * nd["k"] * nd["k"]
            w = rng.normal(0, np.sqrt(2.0 / fan_in),
                           size=(nd["cout"], cin_g, nd["k"], nd["k"]))
            params[nd["id"] + ".w"] = w.astype(np.float32)
            if nd.get("bn", False):
                params[nd["id"] + ".bn.g"] = np.ones(nd["cout"], np.float32)
                params[nd["id"] + ".bn.b"] = np.zeros(nd["cout"], np.float32)
            else:
                params[nd["id"] + ".b"] = np.zeros(nd["cout"], np.float32)
        elif nd["op"] == "dense":
            w = rng.normal(0, np.sqrt(2.0 / nd["cin"]), size=(nd["cout"], nd["cin"]))
            params[nd["id"] + ".w"] = w.astype(np.float32)
            params[nd["id"] + ".b"] = np.zeros(nd["cout"], np.float32)
    return params


def init_bn_state(nodes: List[dict]) -> Dict[str, np.ndarray]:
    state: Dict[str, np.ndarray] = {}
    for nd in nodes:
        if nd["op"] == "conv" and nd.get("bn", False):
            state[nd["id"] + ".bn.mean"] = np.zeros(nd["cout"], np.float32)
            state[nd["id"] + ".bn.var"] = np.ones(nd["cout"], np.float32)
    return state


# --------------------------------------------------------------------------
# JAX executor (training / python-side eval)
# --------------------------------------------------------------------------


def _conv2d(x, w, stride: int, pad: int, groups: int):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        feature_group_count=groups,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def apply_graph(nodes: List[dict], params: Dict, state: Dict, x, train: bool):
    """Run the graph. Returns (output, new_state). ``state`` holds BN
    running statistics; in train mode batch statistics are used and the
    running stats are updated with momentum BN_MOM."""
    vals = {"in": x}
    new_state = dict(state)
    for nd in nodes:
        op, nid = nd["op"], nd["id"]
        if op == "input":
            continue
        a = vals[nd["inputs"][0]] if nd["inputs"] else None
        if op == "conv":
            y = _conv2d(a, params[nid + ".w"], nd["stride"], nd["pad"], nd["groups"])
            if nd.get("bn", False):
                if train:
                    mean = jnp.mean(y, axis=(0, 2, 3))
                    var = jnp.var(y, axis=(0, 2, 3))
                    new_state[nid + ".bn.mean"] = (
                        BN_MOM * state[nid + ".bn.mean"] + (1 - BN_MOM) * mean)
                    new_state[nid + ".bn.var"] = (
                        BN_MOM * state[nid + ".bn.var"] + (1 - BN_MOM) * var)
                else:
                    mean = state[nid + ".bn.mean"]
                    var = state[nid + ".bn.var"]
                inv = params[nid + ".bn.g"] / jnp.sqrt(var + BN_EPS)
                y = (y - mean[None, :, None, None]) * inv[None, :, None, None] \
                    + params[nid + ".bn.b"][None, :, None, None]
            else:
                y = y + params[nid + ".b"][None, :, None, None]
            if nd["relu"]:
                y = jax.nn.relu(y)
        elif op == "dense":
            y = vals[nd["inputs"][0]] @ params[nid + ".w"].T + params[nid + ".b"]
            if nd["relu"]:
                y = jax.nn.relu(y)
        elif op == "add":
            y = vals[nd["inputs"][0]] + vals[nd["inputs"][1]]
            if nd["relu"]:
                y = jax.nn.relu(y)
        elif op == "relu":
            y = jax.nn.relu(a)
        elif op == "avgpool":
            k, s = nd["k"], nd["stride"]
            y = jax.lax.reduce_window(a, 0.0, jax.lax.add, (1, 1, k, k),
                                      (1, 1, s, s), "VALID") / (k * k)
        elif op == "gpool":
            y = jnp.mean(a, axis=(2, 3))
        elif op == "upsample":
            y = jnp.repeat(jnp.repeat(a, 2, axis=2), 2, axis=3)
        elif op == "concat":
            y = jnp.concatenate([vals[i] for i in nd["inputs"]], axis=1)
        else:
            raise ValueError(f"unknown op {op}")
        vals[nid] = y
    return vals[nodes[-1]["id"]], new_state


# --------------------------------------------------------------------------
# BN folding + export IR
# --------------------------------------------------------------------------


def fold_bn(nodes: List[dict], params: Dict[str, np.ndarray],
            state: Dict[str, np.ndarray]) -> Tuple[List[dict], Dict[str, np.ndarray]]:
    """Fold BatchNorm into conv weight+bias; return (export IR, weights).

    w' = w * g/sqrt(var+eps)   (per out-channel)
    b' = beta - g*mean/sqrt(var+eps)
    """
    out_nodes: List[dict] = []
    weights: Dict[str, np.ndarray] = {}
    for nd in nodes:
        nd = dict(nd)
        nid = nd["id"]
        if nd["op"] == "conv":
            w = np.asarray(params[nid + ".w"], np.float32)
            if nd.pop("bn", False):
                g = np.asarray(params[nid + ".bn.g"], np.float32)
                beta = np.asarray(params[nid + ".bn.b"], np.float32)
                mean = np.asarray(state[nid + ".bn.mean"], np.float32)
                var = np.asarray(state[nid + ".bn.var"], np.float32)
                inv = g / np.sqrt(var + BN_EPS)
                w = w * inv[:, None, None, None]
                b = beta - mean * inv
            else:
                b = np.asarray(params[nid + ".b"], np.float32)
            weights[nid + ".w"] = w
            weights[nid + ".b"] = b
        elif nd["op"] == "dense":
            weights[nid + ".w"] = np.asarray(params[nid + ".w"], np.float32)
            weights[nid + ".b"] = np.asarray(params[nid + ".b"], np.float32)
        out_nodes.append(nd)
    return out_nodes, weights
