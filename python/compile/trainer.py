"""Build-time FP32 training of the micro-network zoo.

This is the "pretrained torchvision checkpoint" substitute: each model is
trained from scratch (hand-rolled Adam, cross-entropy) on the synthetic
dataset and its BN-folded weights are exported for the rust PTQ pipeline.
Runs exactly once, inside ``make artifacts``; never on the request path.
"""

from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import datagen, models


def _adam_update(params, grads, m, v, t, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        new_m[k] = b1 * m[k] + (1 - b1) * g
        new_v[k] = b2 * v[k] + (1 - b2) * g * g
        mhat = new_m[k] / (1 - b1 ** t)
        vhat = new_v[k] / (1 - b2 ** t)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_p, new_m, new_v


def _ce_loss(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], axis=-1))


def _seg_ce_loss(logits, masks):
    # logits [N,C,H,W], masks [N,H,W]
    logp = jax.nn.log_softmax(logits, axis=1)
    onehot = jax.nn.one_hot(masks, logits.shape[1], axis=1)
    return -jnp.mean(jnp.sum(logp * onehot, axis=1))


def train_model(name: str, steps: int, seed: int = 0,
                n_train: int = 4096, batch: int = 32,
                verbose: bool = True) -> Tuple[list, Dict[str, np.ndarray], dict]:
    """Train one model; returns (export_ir, folded_weights, report)."""
    nodes = models.BUILDERS[name]()
    task = models.TASKS[name]
    gen = datagen.gen_shapes if task == "seg" else datagen.gen_gabor
    xs, ys = gen(n_train, seed=seed + 1)
    xv, yv = gen(1024, seed=seed + 2)

    params = {k: jnp.asarray(v) for k, v in models.init_params(nodes, seed).items()}
    state = {k: jnp.asarray(v) for k, v in models.init_bn_state(nodes).items()}
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in params.items()}

    loss_fn = _seg_ce_loss if task == "seg" else _ce_loss

    @jax.jit
    def step_fn(params, state, m, v, t, bx, by):
        def loss(p):
            logits, new_state = models.apply_graph(nodes, p, state, bx, train=True)
            return loss_fn(logits, by), new_state
        (l, new_state), grads = jax.value_and_grad(loss, has_aux=True)(params)
        params, m, v = _adam_update(params, grads, m, v, t)
        return params, new_state, m, v, l

    @jax.jit
    def eval_fn(params, state, bx):
        logits, _ = models.apply_graph(nodes, params, state, bx, train=False)
        return logits

    rng = np.random.default_rng(seed + 3)
    t0 = time.time()
    losses = []
    for t in range(1, steps + 1):
        idx = rng.integers(0, n_train, size=batch)
        bx, by = jnp.asarray(xs[idx]), jnp.asarray(ys[idx])
        params, state, m, v, l = step_fn(params, state, m, v, float(t), bx, by)
        losses.append(float(l))
        if verbose and (t % max(1, steps // 5) == 0 or t == 1):
            print(f"  [{name}] step {t}/{steps} loss={float(l):.4f}")

    # validation
    correct, total = 0, 0
    inter = np.zeros(4); union = np.zeros(4)
    for i in range(0, len(xv), 128):
        logits = np.asarray(eval_fn(params, state, jnp.asarray(xv[i:i + 128])))
        if task == "cls":
            pred = logits.argmax(-1)
            correct += int((pred == yv[i:i + 128]).sum()); total += len(pred)
        else:
            pred = logits.argmax(1)
            gt = yv[i:i + 128]
            for c in range(4):
                inter[c] += np.sum((pred == c) & (gt == c))
                union[c] += np.sum((pred == c) | (gt == c))
            total += len(pred)
    if task == "cls":
        metric = 100.0 * correct / total
        metric_name = "top1"
    else:
        metric = 100.0 * float(np.mean(inter / np.maximum(union, 1)))
        metric_name = "miou"

    export_ir, weights = models.fold_bn(
        nodes, {k: np.asarray(p) for k, p in params.items()},
        {k: np.asarray(s) for k, s in state.items()})
    report = {"model": name, "task": task, "steps": steps,
              metric_name: round(metric, 2),
              "train_secs": round(time.time() - t0, 1),
              "final_loss": round(float(np.mean(losses[-20:])), 4)}
    if verbose:
        print(f"  [{name}] fp32 {metric_name}={metric:.2f} "
              f"({time.time() - t0:.0f}s, {steps} steps)")
    return export_ir, weights, report
