"""L2: the AdaRound per-layer optimization step as a single JAX graph.

One call = one full iteration of the paper's eq. (25):

    loss = ||f_a(T) - f_a(W~ X)||^2 / numel  +  lam * f_reg(V; beta)
    grad = dloss/dV      (through the custom-vjp Pallas pair + jnp f_reg)
    (V, m, v) <- Adam(V, m, v, grad, t, lr)

The whole thing is lowered once per (rows, cols, batch, relu) shape bucket
to a single HLO artifact that the rust coordinator executes in a loop —
python never runs on the request path.

Inputs  : V[r,c] m[r,c] v[r,c] t[] X[c,B] T[r,B] W[r,c] s[r,1] b[r,1]
          beta[] lam[] lr[] n[] p[]          (all f32)
Outputs : (V', m', v', loss[], mse[])
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels import relax, softquant

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def make_adaround_step(relu: bool, use_pallas: bool = True):
    """Build the step function for a given activation variant."""

    def objective(v_opt, w, s, b, x, t, beta, lam, n, p):
        if use_pallas:
            y = softquant.softquant_matmul(w, v_opt, s, x, n, p)
        else:  # pure-jnp fallback (oracle path, used in tests)
            from .kernels import ref
            y = ref.softquant_matmul_ref(w, v_opt, s, x, n, p)
        y = y + b  # layer bias participates in the (ReLU-)reconstruction
        tt = t
        if relu:
            y = jnp.maximum(y, 0.0)
            tt = jnp.maximum(t, 0.0)
        mse = jnp.mean((y - tt) ** 2)
        loss = mse + lam * relax.f_reg(v_opt, beta)
        return loss, mse

    def step(v_opt, m, v2, t_step, x, t_target, w, s, b, beta, lam, lr, n, p):
        (loss, mse), grad = jax.value_and_grad(objective, has_aux=True)(
            v_opt, w, s, b, x, t_target, beta, lam, n, p)
        m_new = ADAM_B1 * m + (1.0 - ADAM_B1) * grad
        v_new = ADAM_B2 * v2 + (1.0 - ADAM_B2) * grad * grad
        mhat = m_new / (1.0 - ADAM_B1 ** t_step)
        vhat = v_new / (1.0 - ADAM_B2 ** t_step)
        v_upd = v_opt - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        return v_upd, m_new, v_new, loss, mse

    return step


def step_example_args(rows: int, cols: int, batch: int):
    """ShapeDtypeStructs matching the step signature (for jit.lower)."""
    f32 = jnp.float32
    mat = jax.ShapeDtypeStruct((rows, cols), f32)
    scal = jax.ShapeDtypeStruct((), f32)
    return (
        mat, mat, mat, scal,
        jax.ShapeDtypeStruct((cols, batch), f32),
        jax.ShapeDtypeStruct((rows, batch), f32),
        mat,
        jax.ShapeDtypeStruct((rows, 1), f32),
        jax.ShapeDtypeStruct((rows, 1), f32),
        scal, scal, scal, scal, scal,
    )


def make_qlinear_fwd():
    """Inference-path quantized matmul (see kernels/qlinear.py)."""
    from .kernels import qlinear

    def fwd(w, r, s, b, x, n, p):
        return qlinear.qlinear_matmul(w, r, s, x, n, p) + b

    return fwd


def qlinear_example_args(rows: int, cols: int, batch: int):
    f32 = jnp.float32
    mat = jax.ShapeDtypeStruct((rows, cols), f32)
    scal = jax.ShapeDtypeStruct((), f32)
    return (mat, mat, jax.ShapeDtypeStruct((rows, 1), f32),
            jax.ShapeDtypeStruct((rows, 1), f32),
            jax.ShapeDtypeStruct((cols, batch), f32), scal, scal)
