"""Synthetic-but-learnable vision datasets (build-time data substrate).

The paper evaluates on ImageNet / Pascal VOC with pretrained torchvision
models.  Neither the data nor the checkpoints are available here, so we
substitute procedurally generated datasets that a small CNN genuinely has to
*learn* (texture orientation/frequency discrimination and shape
segmentation), preserving the phenomena AdaRound is about: 4-bit
round-to-nearest destroys accuracy, adaptive rounding recovers it.
See DESIGN.md §1 for the substitution argument.

Datasets
--------
``gabor``   10-class classification, 3x32x32.  Class c => oriented sinusoid
            with orientation theta = pi*c/10 and per-class frequency, random
            phase/offset, colored tint, additive noise.
``checker`` the *shifted-domain* set for the Fig-4 analog: axis-aligned
            checker/stripe textures (different family, same label count).
``shapes``  segmentation, 3x32x32 -> 4 classes per pixel
            (0=bg, 1=disk, 2=square, 3=cross) on a noisy textured background.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

IMG = 32
NUM_CLASSES = 10
SEG_CLASSES = 4


def _coords() -> Tuple[np.ndarray, np.ndarray]:
    y, x = np.meshgrid(np.arange(IMG), np.arange(IMG), indexing="ij")
    return x.astype(np.float32), y.astype(np.float32)


def _gabor_pattern(rng, xs, ys, cls: int) -> np.ndarray:
    theta = np.pi * cls / NUM_CLASSES
    freq = 2.0 + 2.0 * (cls % 2)  # alternate 2 / 4 cycles
    phase = rng.uniform(0, 2 * np.pi)
    proj = (xs * np.cos(theta) + ys * np.sin(theta)) / IMG
    return np.sin(2 * np.pi * freq * proj + phase)


def gen_gabor(n: int, seed: int, noise: float = 1.1) -> Tuple[np.ndarray, np.ndarray]:
    """Oriented-texture classification set. Returns (x[n,3,32,32] f32, y[n] i32).

    Difficulty is tuned so the FP32 micro-networks land around ~90% top-1
    (leaving headroom for the paper's method gradations): random signal
    amplitude, a *distractor* pattern from another class mixed in at random
    strength, and strong pixel noise."""
    rng = np.random.default_rng(seed)
    xs, ys = _coords()
    labels = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    imgs = np.empty((n, 3, IMG, IMG), dtype=np.float32)
    for i in range(n):
        c = int(labels[i])
        amp = rng.uniform(0.15, 0.7)
        base = amp * _gabor_pattern(rng, xs, ys, c)
        d = int(rng.integers(0, NUM_CLASSES))
        if d != c:
            base = base + amp * rng.uniform(0.2, 0.9) * _gabor_pattern(rng, xs, ys, d)
        tint = rng.uniform(0.6, 1.0, size=3).astype(np.float32)
        for ch in range(3):
            imgs[i, ch] = base * tint[ch]
        imgs[i] += rng.normal(0, noise, size=(3, IMG, IMG)).astype(np.float32)
    return imgs.astype(np.float32), labels


def gen_checker(n: int, seed: int, noise: float = 0.7) -> Tuple[np.ndarray, np.ndarray]:
    """Shifted-domain texture set (checker/stripe family), same 10 labels."""
    rng = np.random.default_rng(seed)
    xs, ys = _coords()
    labels = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    imgs = np.empty((n, 3, IMG, IMG), dtype=np.float32)
    for i in range(n):
        c = int(labels[i])
        period = 2 + c  # class sets the checker period
        off = rng.integers(0, period, size=2)
        cells = ((xs + off[0]) // period + (ys + off[1]) // period) % 2
        base = cells * 2.0 - 1.0
        tint = rng.uniform(0.5, 1.0, size=3).astype(np.float32)
        for ch in range(3):
            imgs[i, ch] = base * tint[ch]
        imgs[i] += rng.normal(0, noise, size=(3, IMG, IMG)).astype(np.float32)
    return imgs.astype(np.float32), labels


def gen_shapes(n: int, seed: int, noise: float = 0.45) -> Tuple[np.ndarray, np.ndarray]:
    """Segmentation set. Returns (x[n,3,32,32] f32, y[n,32,32] i32)."""
    rng = np.random.default_rng(seed)
    xs, ys = _coords()
    imgs = np.empty((n, 3, IMG, IMG), dtype=np.float32)
    masks = np.zeros((n, IMG, IMG), dtype=np.int32)
    for i in range(n):
        # textured background
        theta = rng.uniform(0, np.pi)
        proj = (xs * np.cos(theta) + ys * np.sin(theta)) / IMG
        bg = 0.3 * np.sin(2 * np.pi * 3.0 * proj + rng.uniform(0, 2 * np.pi))
        img = np.stack([bg, bg, bg]).astype(np.float32)
        mask = np.zeros((IMG, IMG), dtype=np.int32)
        for _ in range(rng.integers(1, 4)):
            kind = int(rng.integers(1, SEG_CLASSES))
            cx, cy = rng.uniform(6, IMG - 6, size=2)
            r = rng.uniform(3, 6)
            if kind == 1:  # disk
                sel = (xs - cx) ** 2 + (ys - cy) ** 2 <= r * r
            elif kind == 2:  # square
                sel = (np.abs(xs - cx) <= r) & (np.abs(ys - cy) <= r)
            else:  # cross
                sel = ((np.abs(xs - cx) <= r) & (np.abs(ys - cy) <= 1.5)) | (
                    (np.abs(ys - cy) <= r) & (np.abs(xs - cx) <= 1.5)
                )
            mask[sel] = kind
            color = rng.uniform(0.5, 1.0, size=3)
            for ch in range(3):
                img[ch][sel] = color[ch] * (1.0 if kind != 2 else -1.0)
        img += rng.normal(0, noise, size=img.shape).astype(np.float32)
        imgs[i] = img
        masks[i] = mask
    return imgs.astype(np.float32), masks


GENERATORS = {"gabor": gen_gabor, "checker": gen_checker, "shapes": gen_shapes}
