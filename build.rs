// Toolchain gate for the AVX-512 VNNI kernels.
//
// The AVX-512 intrinsics and their `#[target_feature]` strings were
// stabilized in Rust 1.89; on older toolchains the `tensor::int8::kernel`
// AVX-512 module must not be compiled at all. We probe `rustc --version`
// and emit the `pallas_avx512` cfg only when the compiler is new enough,
// so the crate builds unchanged on older stable toolchains (the dispatch
// layer then simply never offers the AVX-512 candidate).

use std::process::Command;

fn rustc_minor() -> Option<(u32, u32)> {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // "rustc 1.89.0 (abc 2025-08-01)" — second whitespace field is the version
    let ver = text.split_whitespace().nth(1)?;
    let mut parts = ver.split(['.', '-', '+']);
    let major = parts.next()?.parse().ok()?;
    let minor = parts.next()?.parse().ok()?;
    Some((major, minor))
}

fn main() {
    // keep `cargo clippy -- -D warnings` happy about the custom cfg
    println!("cargo:rustc-check-cfg=cfg(pallas_avx512)");
    if let Some((major, minor)) = rustc_minor() {
        if (major, minor) >= (1, 89) {
            println!("cargo:rustc-cfg=pallas_avx512");
        }
    }
    println!("cargo:rerun-if-changed=build.rs");
}
