//! Bit-width sweep: where does round-to-nearest break, and how far down
//! does AdaRound hold? (The "who wins, where is the crossover" view of the
//! paper's headline claim.)
//!
//!     cargo run --release --example bitwidth_sweep [-- model]

use adaround::coordinator::{Method, Pipeline, PipelineConfig};
use adaround::nn::ForwardOptions;
use adaround::runtime::Runtime;
use adaround::util::Rng;

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "micro18".into());
    let rt = Runtime::new(&adaround::artifacts_dir())?;
    let model = rt.manifest.load_model(&name)?;
    let (calib, _) = rt.manifest.load_dataset(
        if model.task == "seg" { "calib_shapes" } else { "calib_gabor" })?;
    let (vx, vy) = rt.manifest.load_dataset(
        if model.task == "seg" { "val_shapes" } else { "val_gabor" })?;

    let fp32 = adaround::eval::top1(&model, &vx, &vy, &ForwardOptions::default(), 64);
    println!("{name}: fp32 = {fp32:.2}%");
    println!("{:>5} {:>12} {:>12} {:>10}", "bits", "nearest", "adaround", "gap");
    for bits in [8u32, 4, 3, 2] {
        let mut row = Vec::new();
        for method in [Method::Nearest, Method::AdaRound] {
            let cfg = PipelineConfig { method, bits, ..Default::default() };
            let pipe = Pipeline::new(&model, cfg, Some(&rt));
            let qm = pipe.quantize(&calib, &mut Rng::new(7))?;
            row.push(adaround::eval::top1(&model, &vx, &vy, &qm.opts(), 64));
        }
        println!("{bits:>5} {:>11.2}% {:>11.2}% {:>+9.2}", row[0], row[1], row[1] - row[0]);
    }
    Ok(())
}
