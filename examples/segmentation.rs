//! Domain-specific example: post-training quantization of the
//! encoder-decoder segmentation network (the paper's DeeplabV3+ analog,
//! §5.2 "Semantic segmentation"), reporting mIOU.
//!
//!     cargo run --release --example segmentation

use adaround::coordinator::{Method, Pipeline, PipelineConfig};
use adaround::eval::miou;
use adaround::nn::ForwardOptions;
use adaround::runtime::Runtime;
use adaround::util::Rng;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(&adaround::artifacts_dir())?;
    let model = rt.manifest.load_model("segnet")?;
    let (calib, _) = rt.manifest.load_dataset("calib_shapes")?;
    let (vx, vy) = rt.manifest.load_dataset("val_shapes")?;

    let fp = miou(&model, &vx, &vy, &ForwardOptions::default(), 32, 4);
    println!("segnet fp32 mIOU: {fp:.2}%");

    for (label, method, bits, act) in [
        ("nearest  W2/A8  ", Method::Nearest, 2u32, Some(8u32)),
        ("DFQ      W2/A8  ", Method::Dfq, 2, Some(8)),
        ("AdaRound W2/A32 ", Method::AdaRound, 2, None),
        ("AdaRound W2/A8  ", Method::AdaRound, 2, Some(8)),
    ] {
        let cfg = PipelineConfig { method, bits, act_bits: act, ..Default::default() };
        let pipe = Pipeline::new(&model, cfg, Some(&rt));
        let qm = pipe.quantize(&calib, &mut Rng::new(3))?;
        let m = miou(&pipe.work, &vx, &vy, &qm.opts(), 32, 4);
        println!("{label}: mIOU {m:.2}%");
    }
    Ok(())
}
