//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! 1. Load the pretrained micro18 checkpoint (trained at `make artifacts`).
//! 2. Quantize its weights to 2 bits with **PJRT-driven AdaRound** — every
//!    optimization step executes the AOT HLO artifact whose hot-spot is the
//!    Pallas soft-quant matmul pair (L1), fused with f_reg + Adam (L2),
//!    scheduled by this rust coordinator (L3). No Python anywhere.
//! 3. Quantize activations to 8 bits from the calibration set.
//! 4. Serve the validation set in batches and report accuracy, latency
//!    percentiles and throughput — the numbers EXPERIMENTS.md records.
//!
//!     make artifacts && cargo run --release --example e2e_ptq_serve

use adaround::coordinator::{Method, Pipeline, PipelineConfig};
use adaround::data::chunks;
use adaround::nn::ForwardOptions;
use adaround::runtime::Runtime;
use adaround::tensor::Tensor;
use adaround::util::stats::percentile;
use adaround::util::{Rng, Stopwatch};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(&adaround::artifacts_dir())?;
    let model = rt.manifest.load_model("micro18")?;
    let (calib, _) = rt.manifest.load_dataset("calib_gabor")?;
    let (val_x, val_y) = rt.manifest.load_dataset("val_gabor")?;
    println!("model micro18: {} params, {} quantizable layers",
             model.num_params(), model.quant_layers().len());

    // --- quantize (PJRT-driven AdaRound, 2-bit weights, 8-bit activations)
    let cfg = PipelineConfig {
        method: Method::AdaRoundPjrt,
        bits: 2,
        act_bits: Some(8),
        ..Default::default()
    };
    let sw = Stopwatch::start();
    let pipe = Pipeline::new(&model, cfg, Some(&rt));
    let qm = pipe.quantize(&calib, &mut Rng::new(0))?;
    println!(
        "quantized in {:.1}s ({} HLO executables compiled, {} layer problems)",
        sw.secs(),
        rt.compiled_count(),
        qm.stats.len()
    );
    for s in &qm.stats {
        println!(
            "  {:<5} {:>4}x{:<4} recon-mse {:.3e} -> {:.3e}  ({:.1}% flipped)",
            s.id, s.rows, s.cols, s.mse_before, s.mse_after, 100.0 * s.flipped_frac
        );
    }

    // --- serve ---
    let fp32 = adaround::eval::top1(&model, &val_x, &val_y, &ForwardOptions::default(), 64);
    let n = val_x.shape[0];
    let per: usize = val_x.shape[1..].iter().product();
    let batch = 64;
    let mut lat_ms = Vec::new();
    let mut correct = 0usize;
    let opts = qm.opts();
    let sw = Stopwatch::start();
    for (s, e) in chunks(n, batch) {
        let t0 = Stopwatch::start();
        let xb = Tensor::from_vec(
            &[e - s, val_x.shape[1], val_x.shape[2], val_x.shape[3]],
            val_x.data[s * per..e * per].to_vec(),
        );
        let logits = model.forward(&xb, &opts);
        for (i, p) in logits.argmax_rows().iter().enumerate() {
            if *p as i32 == val_y.data[s + i] {
                correct += 1;
            }
        }
        lat_ms.push(t0.millis());
    }
    let total = sw.secs();
    let acc = 100.0 * correct as f64 / n as f64;
    println!("\n== serving report ==");
    println!("fp32 top-1        : {fp32:.2}%");
    println!("W2/A8 top-1       : {acc:.2}%   (drop {:.2} pts)", fp32 - acc);
    println!("batches served    : {} x {batch} images", lat_ms.len());
    println!("latency p50 / p95 : {:.1} / {:.1} ms", percentile(&lat_ms, 50.0),
             percentile(&lat_ms, 95.0));
    println!("throughput        : {:.0} images/s", n as f64 / total);
    Ok(())
}
