//! Quickstart: quantize a pretrained micro-network to 2-bit weights with
//! AdaRound and compare against round-to-nearest.
//!
//!     make artifacts && cargo run --release --example quickstart

use adaround::coordinator::{Method, Pipeline, PipelineConfig};
use adaround::nn::ForwardOptions;
use adaround::runtime::Runtime;
use adaround::util::Rng;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(&adaround::artifacts_dir())?;
    let model = rt.manifest.load_model("micro18")?;
    let (calib, _) = rt.manifest.load_dataset("calib_gabor")?;
    let (val_x, val_y) = rt.manifest.load_dataset("val_gabor")?;

    let fp32 = adaround::eval::top1(&model, &val_x, &val_y, &ForwardOptions::default(), 64);
    println!("fp32 top-1: {fp32:.2}%");

    for method in [Method::Nearest, Method::AdaRound] {
        let cfg = PipelineConfig { method, bits: 2, ..Default::default() };
        let pipe = Pipeline::new(&model, cfg, Some(&rt));
        let qm = pipe.quantize(&calib, &mut Rng::new(0))?;
        let acc = adaround::eval::top1(&model, &val_x, &val_y, &qm.opts(), 64);
        println!("{:<10} 2-bit top-1: {acc:.2}%", method.name());
    }
    Ok(())
}
