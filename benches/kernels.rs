//! Kernel-level benchmarks (L3 native hot paths + PJRT artifact execution).
//!
//!     cargo bench --bench kernels
//!
//! Covers: blocked matmul, im2col conv, fake-quant, the native AdaRound
//! step (fwd+bwd+Adam, workspace path — zero per-iteration allocation),
//! the PJRT HLO step execution, the QUBO solvers. These are the
//! per-iteration costs behind every table's wall-clock.
//!
//! Besides the stdout table, results are written to `BENCH_kernels.json`
//! (name, mean_ms, p50_ms, p95_ms, iters, throughput, plus the thread
//! count) so the perf trajectory is machine-trackable across PRs. Compare
//! thread scaling with e.g.:
//!
//!     PALLAS_THREADS=1 cargo bench --bench kernels
//!     PALLAS_THREADS=8 cargo bench --bench kernels

use std::collections::BTreeMap;

use adaround::adaround::{Adam, LayerProblem, StepWorkspace};
use adaround::quant::{fake_quant_nearest, rounding_mask, QuantGrid, RoundingMode};
use adaround::qubo::{solve_cem, solve_tabu, CemParams, QuboProblem, TabuParams};
use adaround::runtime::{Runtime, StepState};
use adaround::tensor::int8::kernel::{
    autotune, gemm_conv4_packed_into, gemm_conv_packed_into, gemm_dense4_packed_into,
    gemm_dense_packed_into, Kernel, PackedConv, PackedConv4, PackedDense, PackedDense4,
};
use adaround::tensor::int8::{gemm_i8_into, gemm_u8_bt_into};
use adaround::tensor::{conv2d, matmul, Conv2dParams, Tensor};
use adaround::util::bench::{Bench, BenchResult};
use adaround::util::{parallel, Json, Rng};

fn rnd(shape: &[usize], rng: &mut Rng) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(0.0, 0.5)).collect())
}

fn record(results: &mut Vec<BenchResult>, r: BenchResult) {
    r.print();
    results.push(r);
}

fn write_json(results: &[BenchResult], path: &str) {
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("kernels".to_string()));
    root.insert("threads".to_string(), Json::Num(parallel::num_threads() as f64));
    let entries: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut o = BTreeMap::new();
            o.insert("name".to_string(), Json::Str(r.name.clone()));
            o.insert("mean_ms".to_string(), Json::Num(r.mean_ms));
            o.insert("p50_ms".to_string(), Json::Num(r.p50_ms));
            o.insert("p95_ms".to_string(), Json::Num(r.p95_ms));
            o.insert("iters".to_string(), Json::Num(r.iters as f64));
            o.insert(
                "throughput".to_string(),
                r.throughput.map(Json::Num).unwrap_or(Json::Null),
            );
            Json::Obj(o)
        })
        .collect();
    root.insert("results".to_string(), Json::Arr(entries));
    let text = Json::Obj(root).to_string_pretty();
    match std::fs::write(path, text) {
        Ok(()) => println!("(wrote {path})"),
        Err(e) => eprintln!("(could not write {path}: {e})"),
    }
}

fn main() {
    let mut rng = Rng::new(1);
    let b = Bench::default();
    let mut results: Vec<BenchResult> = Vec::new();
    println!("== kernel benchmarks (threads: {}) ==", parallel::num_threads());

    // matmul at the pipeline's dominant shapes
    for (m, k, n) in [(32usize, 288usize, 192usize), (8, 27, 2048), (64, 256, 1024)] {
        let a = rnd(&[m, k], &mut rng);
        let x = rnd(&[k, n], &mut rng);
        let flops = 2 * m * k * n;
        let r = b.run_with_items(&format!("matmul {m}x{k}x{n} (flops/s)"), flops, &mut || {
            std::hint::black_box(matmul(&a, &x));
        });
        record(&mut results, r);
    }

    // conv2d via im2col (micro18 stage shapes; last one depthwise)
    for (c, o, hw, kk, g) in
        [(8usize, 8usize, 32usize, 3usize, 1usize), (16, 16, 16, 3, 1), (16, 16, 16, 3, 16)]
    {
        let x = rnd(&[32, c, hw, hw], &mut rng);
        let w = rnd(&[o, c / g, kk, kk], &mut rng);
        let p = Conv2dParams { k: kk, stride: 1, pad: 1, groups: g };
        let r = b.run_with_items(
            &format!("conv2d {c}->{o} {hw}x{hw} k{kk} g{g} (img/s, batch 32)"),
            32,
            &mut || {
                std::hint::black_box(conv2d(&x, &w, None, p));
            },
        );
        record(&mut results, r);
    }

    // fake-quant + rounding mask (vectorized round/clamp paths)
    let w = rnd(&[32, 288], &mut rng);
    let grid = QuantGrid::per_tensor(0.05, 4);
    let r = b.run_with_items("fake_quant_nearest 32x288 (weights/s)", w.numel(), &mut || {
        std::hint::black_box(fake_quant_nearest(&w, &grid));
    });
    record(&mut results, r);
    let r = b.run_with_items("rounding_mask nearest 32x288 (weights/s)", w.numel(), &mut || {
        let mut mrng = Rng::new(2);
        std::hint::black_box(rounding_mask(&w, &grid, RoundingMode::Nearest, &mut mrng));
    });
    record(&mut results, r);

    // int8 GEMMs at a conv-bucket shape (the serving engine's hot kernel):
    // the old unpacked scalar loop vs the packed micro-kernels across
    // every ISA variant this machine can run. Entry names carry the
    // kernel label; bench-diff skips entries absent from one side, so
    // ISA-specific rows vanish harmlessly on machines without them.
    {
        let (m, k, n) = (32usize, 288usize, 1024usize);
        let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let bq: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
        let mut c = vec![0i32; m * n];
        let r = b.run_with_items(&format!("gemm_i8 {m}x{k}x{n} (MACs/s)"), m * k * n, &mut || {
            c.fill(0);
            gemm_i8_into(&a, &bq, &mut c, m, k, n);
            std::hint::black_box(&c);
        });
        record(&mut results, r);

        // every ISA variant this machine can run (portable always;
        // avx2/avx512/neon when available) — absent rows vanish
        // harmlessly from bench-diff on machines without the ISA
        let kerns: Vec<Kernel> = Kernel::all().into_iter().filter(|kk| kk.available()).collect();
        let packed = PackedConv::pack(&a, m, k);
        for &kern in &kerns {
            let r = b.run_with_items(
                &format!("gemm_i8 packed-{} {m}x{k}x{n} (MACs/s)", kern.name()),
                m * k * n,
                &mut || {
                    gemm_conv_packed_into(kern, &packed.data, m, k, packed.kp, &bq, &mut c, n);
                    std::hint::black_box(&c);
                },
            );
            record(&mut results, r);
        }

        // nibble-packed w4 variant of the same conv shape: half the weight
        // bytes through the same vpmaddwd pipeline, codes in [-8, 7]
        let a4: Vec<i8> = (0..m * k).map(|_| (rng.below(16) as i32 - 8) as i8).collect();
        let packed4 = PackedConv4::pack(&a4, m, k);
        for &kern in &kerns {
            let r = b.run_with_items(
                &format!("gemm_i8 packed4-{} {m}x{k}x{n} (MACs/s)", kern.name()),
                m * k * n,
                &mut || {
                    gemm_conv4_packed_into(kern, &packed4.data, m, k, packed4.kp, &bq, &mut c, n);
                    std::hint::black_box(&c);
                },
            );
            record(&mut results, r);
        }

        // dense orientation: u8 activations x i8 weight rows (A · W^T)
        let act: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
        let wt: Vec<i8> = (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let r = b.run_with_items(
            &format!("gemm_u8_bt scalar {m}x{k}x{n} (MACs/s)"),
            m * k * n,
            &mut || {
                gemm_u8_bt_into(&act, &wt, &mut c, m, k, n);
                std::hint::black_box(&c);
            },
        );
        record(&mut results, r);
        let pdense = PackedDense::pack(&wt, n, k);
        for &kern in &kerns {
            let r = b.run_with_items(
                &format!("gemm_u8_bt packed-{} {m}x{k}x{n} (MACs/s)", kern.name()),
                m * k * n,
                &mut || {
                    gemm_dense_packed_into(kern, &act, &pdense, &mut c, m);
                    std::hint::black_box(&c);
                },
            );
            record(&mut results, r);
        }
        let wt4: Vec<i8> = (0..n * k).map(|_| (rng.below(16) as i32 - 8) as i8).collect();
        let pdense4 = PackedDense4::pack(&wt4, n, k);
        for &kern in &kerns {
            let r = b.run_with_items(
                &format!("gemm_u8_bt packed4-{} {m}x{k}x{n} (MACs/s)", kern.name()),
                m * k * n,
                &mut || {
                    gemm_dense4_packed_into(kern, &act, &pdense4, &mut c, m);
                    std::hint::black_box(&c);
                },
            );
            record(&mut results, r);
        }

        // what one per-shape autotune costs at compile_plan time: times
        // every available (kernel, cfg) candidate on this conv shape and
        // picks the winner — the per-op price of the dispatch layer
        let r = b.run(&format!("autotune conv {m}x{k}x{n}"), || {
            std::hint::black_box(autotune::tune_conv(m, k, n, false));
        });
        record(&mut results, r);
        let r = b.run(&format!("autotune dense {n}x{k}"), || {
            std::hint::black_box(autotune::tune_dense(n, k, false));
        });
        record(&mut results, r);
    }

    // native AdaRound step (loss_grad_into + Adam, reused workspace) at
    // the largest micro18 layer — the optimizer's actual inner loop
    let prob = LayerProblem::new(rnd(&[32, 288], &mut rng), &grid, 0, vec![0.0; 32], true);
    let x = rnd(&[288, 192], &mut rng);
    let t = matmul(&prob.w, &x);
    let mut v = prob.init_v();
    let mut adam = Adam::new(v.numel());
    let mut ws = StepWorkspace::new(32, 288, 192);
    let r = b.run("native adaround step 32x288xB192", || {
        prob.loss_grad_into(&v, &x, &t, 8.0, 0.01, &mut ws);
        adam.step(&mut v.data, &ws.grad, 0.0); // lr 0: keep state stationary
    });
    record(&mut results, r);

    // PJRT HLO step execution at the same bucket (if artifacts exist)
    if std::path::Path::new(&adaround::artifacts_dir()).join("manifest.json").exists() {
        let rt = Runtime::new(&adaround::artifacts_dir()).unwrap();
        if let Ok(exec) = rt.step_exec(32, 288, true) {
            let xb = rnd(&[288, exec.batch], &mut rng);
            let tb = rnd(&[32, exec.batch], &mut rng);
            let s = Tensor::full(&[32, 1], 0.05);
            let bias = Tensor::full(&[32, 1], 0.0);
            let mut state = StepState::new(prob.init_v());
            let r = b.run("pjrt adaround step 32x288xB192", || {
                exec.run(&mut state, &xb, &tb, &prob.w, &s, &bias, 8.0, 0.01, 0.0, -8.0, 7.0)
                    .unwrap();
            });
            record(&mut results, r);
        }
    } else {
        println!("(PJRT step bench skipped: run `make artifacts`)");
    }

    // QUBO solvers on a first-layer-sized row problem
    let wrow = rnd(&[1, 27], &mut rng);
    let xs = rnd(&[27, 512], &mut rng);
    let h = adaround::qubo::gram(&xs);
    let qp = QuboProblem::from_row(&wrow.data, &grid, 0, &h);
    let r = b.run("qubo CEM n=27", || {
        let mut r = Rng::new(3);
        std::hint::black_box(solve_cem(&qp, CemParams::default(), &mut r));
    });
    record(&mut results, r);
    let r = b.run("qubo tabu n=27", || {
        let mut r = Rng::new(3);
        std::hint::black_box(solve_tabu(&qp, TabuParams::default(), &mut r));
    });
    record(&mut results, r);

    write_json(&results, "BENCH_kernels.json");
}
