//! Kernel-level benchmarks (L3 native hot paths + PJRT artifact execution).
//!
//!     cargo bench --bench kernels
//!
//! Covers: blocked matmul, im2col conv, fake-quant, the native AdaRound
//! step (fwd+bwd+Adam), the PJRT HLO step execution, the QUBO solvers.
//! These are the per-iteration costs behind every table's wall-clock.

use adaround::adaround::{Adam, LayerProblem};
use adaround::quant::{fake_quant_nearest, QuantGrid};
use adaround::qubo::{solve_cem, solve_tabu, CemParams, QuboProblem, TabuParams};
use adaround::runtime::{Runtime, StepState};
use adaround::tensor::{conv2d, matmul, Conv2dParams, Tensor};
use adaround::util::bench::Bench;
use adaround::util::Rng;

fn rnd(shape: &[usize], rng: &mut Rng) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(0.0, 0.5)).collect())
}

fn main() {
    let mut rng = Rng::new(1);
    let b = Bench::default();
    println!("== kernel benchmarks ==");

    // matmul at the pipeline's dominant shapes
    for (m, k, n) in [(32usize, 288usize, 192usize), (8, 27, 2048), (64, 256, 1024)] {
        let a = rnd(&[m, k], &mut rng);
        let x = rnd(&[k, n], &mut rng);
        let flops = 2 * m * k * n;
        let r = b.run_with_items(&format!("matmul {m}x{k}x{n} (flops/s)"), flops, &mut || {
            std::hint::black_box(matmul(&a, &x));
        });
        r.print();
    }

    // conv2d via im2col (micro18 stage shapes; last one depthwise)
    for (c, o, hw, kk, g) in
        [(8usize, 8usize, 32usize, 3usize, 1usize), (16, 16, 16, 3, 1), (16, 16, 16, 3, 16)]
    {
        let x = rnd(&[32, c, hw, hw], &mut rng);
        let w = rnd(&[o, c / g, kk, kk], &mut rng);
        let p = Conv2dParams { k: kk, stride: 1, pad: 1, groups: g };
        let r = b.run_with_items(
            &format!("conv2d {c}->{o} {hw}x{hw} k{kk} g{g} (img/s, batch 32)"),
            32,
            &mut || {
                std::hint::black_box(conv2d(&x, &w, None, p));
            },
        );
        r.print();
    }

    // fake-quant
    let w = rnd(&[32, 288], &mut rng);
    let grid = QuantGrid::per_tensor(0.05, 4);
    b.run_with_items("fake_quant_nearest 32x288 (weights/s)", w.numel(), &mut || {
        std::hint::black_box(fake_quant_nearest(&w, &grid));
    })
    .print();

    // native AdaRound step (loss_grad + Adam) at the largest micro18 layer
    let prob = LayerProblem::new(rnd(&[32, 288], &mut rng), &grid, 0, vec![0.0; 32], true);
    let x = rnd(&[288, 192], &mut rng);
    let t = matmul(&prob.w, &x);
    let mut v = prob.init_v();
    let mut adam = Adam::new(v.numel());
    b.run("native adaround step 32x288xB192", || {
        let (_, _, g) = prob.loss_grad(&v, &x, &t, 8.0, 0.01);
        adam.step(&mut v.data, &g.data, 0.0); // lr 0: keep state stationary
    })
    .print();

    // PJRT HLO step execution at the same bucket (if artifacts exist)
    if std::path::Path::new(&adaround::artifacts_dir()).join("manifest.json").exists() {
        let rt = Runtime::new(&adaround::artifacts_dir()).unwrap();
        if let Ok(exec) = rt.step_exec(32, 288, true) {
            let xb = rnd(&[288, exec.batch], &mut rng);
            let tb = rnd(&[32, exec.batch], &mut rng);
            let s = Tensor::full(&[32, 1], 0.05);
            let bias = Tensor::full(&[32, 1], 0.0);
            let mut state = StepState::new(prob.init_v());
            b.run("pjrt adaround step 32x288xB192", || {
                exec.run(&mut state, &xb, &tb, &prob.w, &s, &bias, 8.0, 0.01, 0.0, -8.0, 7.0)
                    .unwrap();
            })
            .print();
        }
    } else {
        println!("(PJRT step bench skipped: run `make artifacts`)");
    }

    // QUBO solvers on a first-layer-sized row problem
    let wrow = rnd(&[1, 27], &mut rng);
    let xs = rnd(&[27, 512], &mut rng);
    let h = adaround::qubo::gram(&xs);
    let qp = QuboProblem::from_row(&wrow.data, &grid, 0, &h);
    b.run("qubo CEM n=27", || {
        let mut r = Rng::new(3);
        std::hint::black_box(solve_cem(&qp, CemParams::default(), &mut r));
    })
    .print();
    b.run("qubo tabu n=27", || {
        let mut r = Rng::new(3);
        std::hint::black_box(solve_tabu(&qp, TabuParams::default(), &mut r));
    })
    .print();
}
