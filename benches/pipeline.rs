//! Pipeline-level benchmark: end-to-end `quantize` wall-clock and
//! calibration layer-forward counts on a deep synthetic model, streaming
//! (O(L)) vs full-replay (O(L²)) sampler, per method. Self-contained —
//! no `make artifacts` — and doubles as an equivalence gate: it fails if
//! the two samplers produce different weights.
//!
//!     cargo bench --bench pipeline
//!
//! Emits `BENCH_pipeline.json` for `adaround bench-diff` (the CI perf
//! gate compares it against the committed `BENCH_baseline_pipeline.json`).

use adaround::cli::quantize::{run_quantize_bench, QuantizeBenchOpts};

fn main() -> anyhow::Result<()> {
    run_quantize_bench(&QuantizeBenchOpts::default())
}
