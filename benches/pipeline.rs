//! Pipeline-level benchmarks: end-to-end PTQ wall-clock per method and the
//! native-vs-PJRT driver and engine comparisons (EXPERIMENTS.md §Perf).
//!
//!     cargo bench --bench pipeline

use adaround::coordinator::{Method, Pipeline, PipelineConfig};
use adaround::nn::ForwardOptions;
use adaround::runtime::Runtime;
use adaround::tensor::Tensor;
use adaround::util::{Rng, Stopwatch};

fn main() -> anyhow::Result<()> {
    let dir = adaround::artifacts_dir();
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        println!("pipeline bench requires `make artifacts`");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let model = rt.manifest.load_model("micro18")?;
    let (calib, _) = rt.manifest.load_dataset("calib_gabor")?;
    println!("== pipeline benchmarks (micro18, 2-bit, calib 256) ==");

    // full-model quantization wall-clock per method (one run each)
    for method in [
        Method::Nearest,
        Method::BiasCorr,
        Method::Omse,
        Method::Ocs,
        Method::Hopfield,
        Method::Ste,
        Method::AdaRound,
        Method::AdaRoundPjrt,
        Method::LocalQuboCem,
    ] {
        let cfg = PipelineConfig { method, bits: 2, ..Default::default() };
        let pipe = Pipeline::new(&model, cfg, Some(&rt));
        let sw = Stopwatch::start();
        let qm = pipe.quantize(&calib, &mut Rng::new(1))?;
        println!(
            "{:<16} {:>8.1}s   (sum recon-mse {:.3e} -> {:.3e})",
            method.name(),
            sw.secs(),
            qm.total_mse_before(),
            qm.total_mse_after()
        );
    }

    // inference engine throughput (native graph executor)
    let (vx, _) = rt.manifest.load_dataset("val_gabor")?;
    let per: usize = vx.shape[1..].iter().product();
    let batch = 64;
    let xb = Tensor::from_vec(&[batch, 3, 32, 32], vx.data[..batch * per].to_vec());
    let sw = Stopwatch::start();
    let reps = 20;
    for _ in 0..reps {
        std::hint::black_box(model.forward(&xb, &ForwardOptions::default()));
    }
    let s = sw.secs() / reps as f64;
    println!(
        "native inference  {:>8.1} ms/batch-of-{batch}  ({:.0} img/s)",
        s * 1e3,
        batch as f64 / s
    );
    Ok(())
}
