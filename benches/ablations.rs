//! Ablation benchmarks for the design choices DESIGN.md §5 calls out:
//! beta schedule, lambda, CEM population/elite, per-tensor vs per-channel.
//! Each run reports the reconstruction MSE achieved (quality) and time.
//!
//!     cargo bench --bench ablations

use adaround::adaround::{
    AdaRoundConfig, BetaSchedule, LayerProblem, NativeOptimizer, RoundingOptimizer,
};
use adaround::quant::{GridMethod, QuantGrid};
use adaround::qubo::{solve_cem, CemParams, QuboProblem};
use adaround::tensor::{matmul, Tensor};
use adaround::util::{Rng, Stopwatch};

fn problem(seed: u64, rows: usize, cols: usize, per_channel: bool) -> (LayerProblem, Tensor, Tensor) {
    let mut rng = Rng::new(seed);
    let w = Tensor::from_vec(
        &[rows, cols],
        (0..rows * cols).map(|_| rng.normal_f32(0.0, 0.3)).collect(),
    );
    let grid = QuantGrid::fit(&w, 2, GridMethod::MseW, per_channel, None);
    let bias = (0..rows).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let prob = LayerProblem::new(w.clone(), &grid, 0, bias, true);
    let x = Tensor::from_vec(
        &[cols, 1024],
        (0..cols * 1024).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
    );
    let mut t = matmul(&w, &x);
    for r in 0..rows {
        let b = prob.bias[r];
        for v in &mut t.data[r * 1024..(r + 1) * 1024] {
            *v += b;
        }
    }
    (prob, x, t)
}

fn run(label: &str, prob: &LayerProblem, x: &Tensor, t: &Tensor, cfg: &AdaRoundConfig) {
    let sw = Stopwatch::start();
    let res = NativeOptimizer.optimize(prob, x, t, cfg, &mut Rng::new(5)).unwrap();
    println!(
        "{label:<46} mse {:.4e} -> {:.4e}  flips {:>5.1}%  {:>6.2}s",
        res.mse_before,
        res.mse_after,
        100.0 * res.flipped_frac,
        sw.secs()
    );
}

fn main() {
    println!("== ablations (32x288 layer, 2-bit, native driver) ==");
    let (prob, x, t) = problem(1, 32, 288, false);

    // beta schedule
    for (label, beta) in [
        ("beta 20->2 warmup 0.2 (default)", BetaSchedule { start: 20.0, end: 2.0, warmup: 0.2 }),
        ("beta 20->2 no warmup", BetaSchedule { start: 20.0, end: 2.0, warmup: 0.0 }),
        ("beta 8->2 warmup 0.2", BetaSchedule { start: 8.0, end: 2.0, warmup: 0.2 }),
        ("beta const 2 (no annealing)", BetaSchedule { start: 2.0, end: 2.0, warmup: 0.2 }),
    ] {
        let cfg = AdaRoundConfig { iters: 800, beta, ..Default::default() };
        run(label, &prob, &x, &t, &cfg);
    }

    // lambda
    for lam in [0.001f32, 0.01, 0.1] {
        let cfg = AdaRoundConfig { iters: 800, lambda: lam, ..Default::default() };
        run(&format!("lambda {lam}"), &prob, &x, &t, &cfg);
    }

    // per-tensor vs per-channel grid (same optimizer budget)
    let (prob_pc, x2, t2) = problem(1, 32, 288, true);
    run("grid per-tensor (ref)", &prob, &x, &t, &AdaRoundConfig { iters: 800, ..Default::default() });
    run("grid per-channel", &prob_pc, &x2, &t2, &AdaRoundConfig { iters: 800, ..Default::default() });

    // CEM population/elite ablation on a QUBO row
    println!("\n== CEM ablation (row n=288, local-MSE QUBO) ==");
    let h = adaround::qubo::gram(&x);
    let qp = QuboProblem::from_row(
        &prob.w.data[..288],
        &QuantGrid::per_tensor(prob.s(0), 2),
        0,
        &h,
    );
    let nearest: Vec<u8> = qp.frac.iter().map(|&f| (f >= 0.5) as u8).collect();
    println!("{:<46} cost {:.4e}", "nearest", qp.eval(&nearest));
    for (pop, elite, iters) in [(32usize, 0.25f64, 30usize), (96, 0.125, 60), (192, 0.0625, 90)] {
        let sw = Stopwatch::start();
        let (_, cost) = solve_cem(
            &qp,
            CemParams { population: pop, elite_frac: elite, iters, alpha: 0.7 },
            &mut Rng::new(9),
        );
        println!(
            "{:<46} cost {:.4e}  {:>6.2}s",
            format!("CEM pop={pop} elite={elite} iters={iters}"),
            cost,
            sw.secs()
        );
    }
}
