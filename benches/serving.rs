//! Serving benchmarks: f32 fake-quant forward vs the int8 engine, plus
//! batched-serving latency under offered load.
//!
//!     cargo bench --bench serving
//!
//! Self-contained (no `make artifacts`): builds a synthetic conv net,
//! quantizes it 8/8 with the native pipeline, compiles the integer plan
//! and measures. Emits `BENCH_serving.json` (imgs/sec per engine per
//! batch size, p50/p99 latency per offered load) for `bench-diff`.

use std::collections::BTreeMap;
use std::time::Duration;

use adaround::coordinator::{Method, Pipeline, PipelineConfig};
use adaround::data::synthetic_stripes;
use adaround::nn::Model;
use adaround::serve::{
    compile_plan, http_offered_load_latencies, infer_body, latency_entry, offered_load_latencies,
    shard_sweep, throughput_entry, BatchPolicy, Batcher, HttpConfig, HttpServer, ServeEngine,
};
use adaround::tensor::Tensor;
use adaround::util::stats::percentile;
use adaround::util::{parallel, Json, Rng, Stopwatch};

/// A mid-size synthetic classifier: conv stack + residual add + pooling
/// + dense head — enough arithmetic that engine differences dominate
/// measurement noise, small enough to quantize in seconds.
fn bench_model(rng: &mut Rng) -> Model {
    let ir = r#"{"task":"cls","ir":[
      {"id":"in","op":"input","inputs":[]},
      {"id":"c1","op":"conv","inputs":["in"],"cin":3,"cout":16,
       "k":3,"stride":1,"pad":1,"groups":1,"relu":true},
      {"id":"c2","op":"conv","inputs":["c1"],"cin":16,"cout":16,
       "k":3,"stride":1,"pad":1,"groups":1,"relu":false},
      {"id":"a1","op":"add","inputs":["c2","c1"],"relu":true},
      {"id":"p1","op":"avgpool","inputs":["a1"],"k":2,"stride":2},
      {"id":"c3","op":"conv","inputs":["p1"],"cin":16,"cout":32,
       "k":3,"stride":1,"pad":1,"groups":1,"relu":true},
      {"id":"g1","op":"gpool","inputs":["c3"]},
      {"id":"d1","op":"dense","inputs":["g1"],"cin":32,"cout":10,"relu":false}
    ]}"#;
    let entry = Json::parse(ir).unwrap();
    let mut w = BTreeMap::new();
    let mut tensor = |shape: &[usize], std: f32, rng: &mut Rng| {
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(0.0, std)).collect())
    };
    w.insert("c1.w".into(), tensor(&[16, 3, 3, 3], 0.2, rng));
    w.insert("c1.b".into(), tensor(&[16], 0.05, rng));
    w.insert("c2.w".into(), tensor(&[16, 16, 3, 3], 0.09, rng));
    w.insert("c2.b".into(), tensor(&[16], 0.05, rng));
    w.insert("c3.w".into(), tensor(&[32, 16, 3, 3], 0.09, rng));
    w.insert("c3.b".into(), tensor(&[32], 0.05, rng));
    w.insert("d1.w".into(), tensor(&[10, 32], 0.2, rng));
    w.insert("d1.b".into(), tensor(&[10], 0.05, rng));
    Model::from_manifest("servebench", &entry, w).unwrap()
}

fn batch_of(x: &Tensor, n: usize) -> Tensor {
    let per: usize = x.shape[1..].iter().product();
    Tensor::from_vec(
        &[n, x.shape[1], x.shape[2], x.shape[3]],
        x.data[..n * per].to_vec(),
    )
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(11);
    let model = bench_model(&mut rng);
    let (calib, _) = synthetic_stripes(96, 3, 32, &mut rng);
    let (val, _) = synthetic_stripes(128, 3, 32, &mut rng);
    println!("== serving benchmarks (threads: {}) ==", parallel::num_threads());

    // 8/8 nearest quantization — the serving configuration
    let cfg = PipelineConfig {
        method: Method::Nearest,
        bits: 8,
        per_channel: true,
        act_bits: Some(8),
        calib_n: 96,
        ..Default::default()
    };
    let qm = Pipeline::new(&model, cfg.clone(), None).quantize(&calib, &mut Rng::new(1))?;
    let mut engine = ServeEngine::compile(&model, &qm, &[3, 32, 32])?;
    let opts = qm.opts();

    // int4 twin: same model and calibration set, weights quantized at 4
    // bits — the pipeline records per-layer wbits, so the compiler packs
    // every conv/dense nibble-packed (w4)
    let cfg4 = PipelineConfig { bits: 4, ..cfg };
    let qm4 = Pipeline::new(&model, cfg4, None).quantize(&calib, &mut Rng::new(1))?;
    let mut engine4 = ServeEngine::compile(&model, &qm4, &[3, 32, 32])?;
    let (wb8, wb4) = (engine.plan.weight_bytes(), engine4.plan.weight_bytes());
    println!(
        "packed weight bytes: w8 plan {wb8}, w4 plan {wb4} ({:.2}x smaller)",
        wb8 as f64 / wb4 as f64
    );
    let autotune_ms = engine.plan.autotune_ms;
    let op_kernels = engine.plan.op_choices();
    println!(
        "autotune: {autotune_ms:.1} ms, per-op choices: {}",
        op_kernels
            .iter()
            .map(|(op, ch)| format!("{op}={}", ch.label()))
            .collect::<Vec<_>>()
            .join(" ")
    );

    // parity: the int8 engine must mirror the fake-quant simulation
    let logits_fq = model.forward(&val, &opts);
    let pred_fq = logits_fq.argmax_rows();
    let pred_i8 = engine.classify(&val);
    let agree = pred_fq.iter().zip(&pred_i8).filter(|(a, b)| a == b).count();
    let agree_frac = agree as f64 / pred_fq.len() as f64;
    println!(
        "argmax parity int8 vs fake-quant: {agree}/{} ({:.1}%)",
        pred_fq.len(),
        100.0 * agree_frac
    );

    let mut results: Vec<Json> = Vec::new();
    let mut speedup_b8 = 0.0f64;
    let reps = 20;
    println!(
        "{:<24} {:>12} {:>12} {:>12} {:>8}",
        "batch", "f32 img/s", "int8 img/s", "int4 img/s", "speedup"
    );
    for batch in [1usize, 8, 32] {
        let xb = batch_of(&val, batch);
        // warmup all paths
        std::hint::black_box(model.forward(&xb, &opts));
        std::hint::black_box(engine.forward(&xb));
        std::hint::black_box(engine4.forward(&xb));
        let sw = Stopwatch::start();
        for _ in 0..reps {
            std::hint::black_box(model.forward(&xb, &opts));
        }
        let f32_s = sw.secs() / reps as f64;
        let sw = Stopwatch::start();
        for _ in 0..reps {
            std::hint::black_box(engine.forward(&xb));
        }
        let int8_s = sw.secs() / reps as f64;
        let sw = Stopwatch::start();
        for _ in 0..reps {
            std::hint::black_box(engine4.forward(&xb));
        }
        let int4_s = sw.secs() / reps as f64;
        let (f32_tp, int8_tp, int4_tp) =
            (batch as f64 / f32_s, batch as f64 / int8_s, batch as f64 / int4_s);
        if batch == 8 {
            speedup_b8 = int8_tp / f32_tp;
        }
        println!(
            "{:<24} {:>12.1} {:>12.1} {:>12.1} {:>7.2}x",
            format!("batch {batch}"),
            f32_tp,
            int8_tp,
            int4_tp,
            int8_tp / f32_tp
        );
        for (engine_name, tp) in [
            ("f32-fake-quant", f32_tp),
            ("int8-engine", int8_tp),
            ("int4-engine", int4_tp),
        ] {
            results.push(throughput_entry(&format!("{engine_name} batch{batch}"), tp));
        }
    }

    // batched serving: latency percentiles at several offered loads
    let per: usize = val.shape[1..].iter().product();
    let pool: Vec<Tensor> = (0..16)
        .map(|i| Tensor::from_vec(&[3, 32, 32], val.data[i * per..(i + 1) * per].to_vec()))
        .collect();
    // depth budget high enough that admission never rejects here: these
    // entries measure queueing latency, and must stay comparable to the
    // pre-admission baselines
    let policy = BatchPolicy {
        max_batch: 32,
        max_wait: Duration::from_millis(2),
        shards: 1,
        depth_budget: 4096,
        ..Default::default()
    };
    let batcher = Batcher::new(engine, policy);
    println!("{:<24} {:>12} {:>12}", "offered load", "p50 ms", "p99 ms");
    for rate in [500.0f64, 2000.0, 8000.0] {
        let n_req = ((rate * 0.4) as usize).max(100);
        let lat = offered_load_latencies(&batcher, &pool, n_req, rate);
        let (p50, p99) = (percentile(&lat, 50.0), percentile(&lat, 99.0));
        println!("{:<24} {:>12.2} {:>12.2}", format!("{rate:.0} img/s"), p50, p99);
        results.push(latency_entry(&format!("serve offered={rate:.0}"), p50, p99));
    }
    batcher.shutdown();

    // the same offered-load shape measured over a real loopback socket:
    // serialize → HTTP → admission → batcher → shard → response. The gap
    // to the in-process entries above is the front-end's cost.
    let engine_http = ServeEngine::compile(&model, &qm, &[3, 32, 32])?;
    let server = HttpServer::bind(
        Batcher::new(engine_http, policy),
        "127.0.0.1:0",
        HttpConfig::default(),
    )?;
    let addr = server.local_addr();
    let bodies: Vec<Vec<u8>> = pool.iter().map(infer_body).collect();
    println!("{:<24} {:>12} {:>12} {:>10}", "http offered load", "p50 ms", "p99 ms", "rejected");
    for rate in [500.0f64, 2000.0] {
        let n_req = ((rate * 0.4) as usize).max(100);
        let (lat, rejected) = http_offered_load_latencies(addr, &bodies, n_req, rate, 4);
        let (p50, p99) = (percentile(&lat, 50.0), percentile(&lat, 99.0));
        println!(
            "{:<24} {:>12.2} {:>12.2} {:>10}",
            format!("{rate:.0} req/s"),
            p50,
            p99,
            rejected
        );
        results.push(latency_entry(&format!("http offered={rate:.0}"), p50, p99));
    }
    server.shutdown();

    // shard scaling under batch-heavy closed-loop load: one engine per
    // core vs the single-engine layout — the first real multi-core
    // serving entries in the bench trajectory
    let (entries, shard_speedup) = shard_sweep(
        || ServeEngine::compile(&model, &qm, &[3, 32, 32]).expect("engine compiled above"),
        policy,
        &pool,
        parallel::num_threads(),
        24,
    );
    results.extend(entries);

    // zero-downtime hot-swap: publish a freshly compiled plan into a
    // live sharded batcher and measure how long until every shard has
    // adopted it — i.e. the old generation's Arc is fully released.
    // Idle shards re-check between batches, so under zero traffic this
    // is bounded by the per-shard idle recheck interval.
    let swap_shards = parallel::num_threads().clamp(2, 4);
    let swap_policy = BatchPolicy { shards: swap_shards, ..policy };
    let swap_batcher = Batcher::new(ServeEngine::compile(&model, &qm, &[3, 32, 32])?, swap_policy);
    let mut adopt_ms: Vec<f64> = Vec::new();
    for _ in 0..8 {
        let plan = compile_plan(&model, &qm, &[3, 32, 32])?;
        let old = swap_batcher.plan();
        let sw = Stopwatch::start();
        swap_batcher.swap_plan(plan).expect("same input geometry");
        while std::sync::Arc::strong_count(&old) > 1 {
            std::thread::sleep(Duration::from_micros(200));
        }
        adopt_ms.push(sw.secs() * 1e3);
    }
    swap_batcher.shutdown();
    let (swap_p50, swap_p99) = (percentile(&adopt_ms, 50.0), percentile(&adopt_ms, 99.0));
    println!(
        "{:<24} {:>12.2} {:>12.2}",
        format!("hot-swap adopt x{swap_shards}"),
        swap_p50,
        swap_p99
    );
    results.push(latency_entry("hot-swap adopt", swap_p50, swap_p99));
    results.push({
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str("plan autotune".to_string()));
        o.insert("mean_ms".to_string(), Json::Num(autotune_ms));
        Json::Obj(o)
    });

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("serving".to_string()));
    root.insert("threads".to_string(), Json::Num(parallel::num_threads() as f64));
    root.insert("parity_agree_frac".to_string(), Json::Num(agree_frac));
    root.insert("int8_speedup_batch8".to_string(), Json::Num(speedup_b8));
    root.insert("weight_bytes_w8".to_string(), Json::Num(wb8 as f64));
    root.insert("weight_bytes_w4".to_string(), Json::Num(wb4 as f64));
    root.insert(
        "op_dtypes_w4_plan".to_string(),
        Json::Arr(
            engine4
                .plan
                .op_dtypes()
                .iter()
                .map(|(id, d)| Json::Str(format!("{id}:{d}")))
                .collect(),
        ),
    );
    root.insert(
        "op_kernels".to_string(),
        Json::Arr(
            op_kernels.iter().map(|(id, ch)| Json::Str(format!("{id}:{}", ch.label()))).collect(),
        ),
    );
    root.insert("autotune_ms".to_string(), Json::Num(autotune_ms));
    root.insert("shard_speedup_max".to_string(), Json::Num(shard_speedup));
    root.insert("results".to_string(), Json::Arr(results));
    std::fs::write("BENCH_serving.json", Json::Obj(root).to_string_pretty())?;
    println!("(wrote BENCH_serving.json)");
    if speedup_b8 < 1.0 {
        println!("WARNING: int8 engine did not beat f32 fake-quant at batch 8");
    }
    Ok(())
}
