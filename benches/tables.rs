//! Table-regeneration benchmarks: wall-clock for quick (reduced-budget)
//! versions of each paper-table driver, so regressions in any stage of the
//! experiment stack show up as timing changes.
//!
//!     cargo bench --bench tables
//!
//! (Full-budget tables are produced by `adaround table <n>`; their outputs
//! are recorded in EXPERIMENTS.md.)

use adaround::cli::common::Ctx;
use adaround::cli::tables::run_table_quick;
use adaround::util::cli::Args;
use adaround::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let dir = adaround::artifacts_dir();
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        println!("tables bench requires `make artifacts`");
        return Ok(());
    }
    let ctx = Ctx::load(&Args::parse(
        vec!["bench".to_string(), "--val-n".into(), "64".into()].into_iter(),
    ))?;
    println!("== table-driver benchmarks (reduced budgets) ==");
    for n in [1usize, 3, 4, 5, 6, 8, 10] {
        let sw = Stopwatch::start();
        // suppress the table's own stdout? keep it: bench output doubles as
        // a smoke test that every driver still runs end to end.
        run_table_quick(&ctx, n)?;
        println!(">>> table {n} (quick): {:.1}s\n", sw.secs());
    }
    Ok(())
}
