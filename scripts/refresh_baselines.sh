#!/bin/sh
# Refresh the committed bench baselines from a local bench run.
#
# The CI regression gate (`adaround bench-diff`) compares BENCH_*.json
# against the committed BENCH_baseline_*.json floors. After a deliberate
# perf change (new kernel variant, autotuner, blocking config), re-run
# the benches on a representative machine and promote the fresh numbers:
#
#   cargo bench --bench kernels && cargo bench --bench serving \
#     && cargo bench --bench pipeline && scripts/refresh_baselines.sh
#
# Entries present in the fresh run but absent from the old baseline are
# picked up automatically — bench-diff skips names the baseline lacks,
# so promoting a run is what arms the gate for newly added entries
# (per-variant kernels, autotune timings, batchN serving rows).
set -eu
cd "$(dirname "$0")/.."

refreshed=0
for new in BENCH_kernels.json BENCH_serving.json BENCH_pipeline.json; do
  base="BENCH_baseline_${new#BENCH_}"
  if [ -f "$new" ]; then
    cp "$new" "$base"
    echo "refreshed $base from $new"
    refreshed=$((refreshed + 1))
  else
    echo "no $new in repo root; run the matching 'cargo bench' first" >&2
  fi
done

[ "$refreshed" -gt 0 ] || { echo "nothing refreshed" >&2; exit 1; }
echo "done — review the diff and commit the new baselines"
